//! `weights.bin` (HXGW) parser: the named-tensor container emitted by
//! `python/compile/aot.py::write_weights`.
//!
//! Format (little endian): magic `HXGW`, u32 version, u32 count, then per
//! tensor: u16 name_len, name utf-8, u8 ndim, u32 dims…, f32 data.

use std::collections::HashMap;
use std::io::Read;

use anyhow::{bail, Context, Result};

/// A host-side named tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }

    /// Number of dim-0 slots (batch rows for KV-cache tensors).
    pub fn slots(&self) -> usize {
        self.dims.first().copied().unwrap_or(0)
    }

    /// Elements per dim-0 slot.
    pub fn slot_elements(&self) -> usize {
        if self.dims.is_empty() {
            0
        } else {
            self.dims[1..].iter().product::<usize>().max(1)
        }
    }

    /// Copy dim-0 row `src_slot` of `src` into row `dst_slot` of `self`
    /// (KV-cache slot insert). The trailing dims must match; the dim-0
    /// extents may differ (e.g. prefill bucket vs session bucket).
    pub fn copy_slot_from(&mut self, dst_slot: usize, src: &Tensor, src_slot: usize) -> Result<()> {
        if self.dims.is_empty() || src.dims.is_empty() || self.dims[1..] != src.dims[1..] {
            bail!(
                "slot copy between incompatible shapes {:?} and {:?}",
                self.dims,
                src.dims
            );
        }
        if dst_slot >= self.slots() || src_slot >= src.slots() {
            bail!(
                "slot copy {src_slot}->{dst_slot} out of range ({} src, {} dst slots)",
                src.slots(),
                self.slots()
            );
        }
        let n = self.slot_elements();
        self.data[dst_slot * n..(dst_slot + 1) * n]
            .copy_from_slice(&src.data[src_slot * n..(src_slot + 1) * n]);
        Ok(())
    }

    /// Zero dim-0 row `slot` (KV-cache slot evict).
    pub fn clear_slot(&mut self, slot: usize) -> Result<()> {
        if self.dims.is_empty() || slot >= self.slots() {
            bail!("clear_slot {slot} out of range for shape {:?}", self.dims);
        }
        let n = self.slot_elements();
        for v in &mut self.data[slot * n..(slot + 1) * n] {
            *v = 0.0;
        }
        Ok(())
    }

    /// Copy the depth range `rows` of dim-0 slot `src_slot` in `src` into
    /// slot `dst_slot` of `self`, per head — the depth-bounded sibling of
    /// [`Self::copy_slot_from`] for rank-4 KV caches `[slots, heads,
    /// max_seq, head_dim]`. Moving only a row's occupied prefix (and, on
    /// scatter-back, just its newest entry) is what keeps the decode
    /// bucket down-shift cheaper than the attention it saves.
    pub fn copy_cache_rows(
        &mut self,
        dst_slot: usize,
        src: &Tensor,
        src_slot: usize,
        rows: std::ops::Range<usize>,
    ) -> Result<()> {
        if self.dims.len() != 4 || src.dims.len() != 4 || self.dims[1..] != src.dims[1..] {
            bail!(
                "cache-row copy between incompatible shapes {:?} and {:?}",
                self.dims,
                src.dims
            );
        }
        let (heads, depth, dh) = (self.dims[1], self.dims[2], self.dims[3]);
        if dst_slot >= self.dims[0] || src_slot >= src.dims[0] {
            bail!(
                "cache-row copy {src_slot}->{dst_slot} out of range ({} src, {} dst slots)",
                src.dims[0],
                self.dims[0]
            );
        }
        if rows.start > rows.end || rows.end > depth {
            bail!("cache rows {rows:?} outside depth {depth}");
        }
        self.copy_cache_rows_between(dst_slot, rows.start, src, src_slot, rows.start, rows.end - rows.start)
    }

    /// Copy `n_rows` cache rows between rank-4 KV tensors whose depth
    /// (`dims[2]`) may differ, per head: rows `[src_row, src_row +
    /// n_rows)` of `src_slot` in `src` land at `[dst_row, dst_row +
    /// n_rows)` of `dst_slot` in `self`. This is the block-granular
    /// engine of the paged KV cache — the same primitive moves a block's
    /// row prefix into dense step scratch (`dst_row = block_index *
    /// block_tokens`), scatters a decode step's newest row back
    /// (`n_rows = 1`), and hands freshly prefilled rows off into blocks.
    /// Heads and head_dim must match; slot counts and depths may not.
    pub fn copy_cache_rows_between(
        &mut self,
        dst_slot: usize,
        dst_row: usize,
        src: &Tensor,
        src_slot: usize,
        src_row: usize,
        n_rows: usize,
    ) -> Result<()> {
        if self.dims.len() != 4
            || src.dims.len() != 4
            || self.dims[1] != src.dims[1]
            || self.dims[3] != src.dims[3]
        {
            bail!(
                "cache-row copy between incompatible shapes {:?} and {:?}",
                self.dims,
                src.dims
            );
        }
        let (heads, dst_depth, dh) = (self.dims[1], self.dims[2], self.dims[3]);
        let src_depth = src.dims[2];
        if dst_slot >= self.dims[0] || src_slot >= src.dims[0] {
            bail!(
                "cache-row copy {src_slot}->{dst_slot} out of range ({} src, {} dst slots)",
                src.dims[0],
                self.dims[0]
            );
        }
        if dst_row + n_rows > dst_depth || src_row + n_rows > src_depth {
            bail!(
                "cache rows src {src_row}+{n_rows} / dst {dst_row}+{n_rows} outside depths {src_depth} / {dst_depth}"
            );
        }
        if n_rows == 0 {
            return Ok(());
        }
        let dst_slot_elems = heads * dst_depth * dh;
        let src_slot_elems = heads * src_depth * dh;
        let len = n_rows * dh;
        for head in 0..heads {
            let d = dst_slot * dst_slot_elems + head * dst_depth * dh + dst_row * dh;
            let s = src_slot * src_slot_elems + head * src_depth * dh + src_row * dh;
            self.data[d..d + len].copy_from_slice(&src.data[s..s + len]);
        }
        Ok(())
    }

    /// Copy `n_rows` cache rows for a span of `head_n` heads between
    /// rank-4 KV tensors whose *head counts* (`dims[1]`) may differ —
    /// the cross-layout engine of the disaggregated KV hand-off. A
    /// prefill replica's per-shard block store holds `heads/tp` heads
    /// per tensor while a [`KvSegment`](crate::coordinator) carries all
    /// heads of a layer in one tensor (and the importing replica may
    /// shard differently), so export/import must address head windows:
    /// rows `[src_row, src_row + n_rows)` of heads `[src_head, src_head
    /// + head_n)` in `src_slot` of `src` land at `[dst_row, ..)` of
    /// heads `[dst_head, ..)` in `dst_slot` of `self`. Only `head_dim`
    /// must match; slot counts, head counts, and depths may all differ.
    #[allow(clippy::too_many_arguments)]
    pub fn copy_cache_head_rows(
        &mut self,
        dst_slot: usize,
        dst_head: usize,
        dst_row: usize,
        src: &Tensor,
        src_slot: usize,
        src_head: usize,
        src_row: usize,
        head_n: usize,
        n_rows: usize,
    ) -> Result<()> {
        if self.dims.len() != 4 || src.dims.len() != 4 || self.dims[3] != src.dims[3] {
            bail!(
                "head-windowed cache-row copy between incompatible shapes {:?} and {:?}",
                self.dims,
                src.dims
            );
        }
        let (dst_heads, dst_depth, dh) = (self.dims[1], self.dims[2], self.dims[3]);
        let (src_heads, src_depth) = (src.dims[1], src.dims[2]);
        if dst_slot >= self.dims[0] || src_slot >= src.dims[0] {
            bail!(
                "head-windowed cache-row copy {src_slot}->{dst_slot} out of range ({} src, {} dst slots)",
                src.dims[0],
                self.dims[0]
            );
        }
        if dst_head + head_n > dst_heads || src_head + head_n > src_heads {
            bail!(
                "head window src {src_head}+{head_n} / dst {dst_head}+{head_n} outside head counts {src_heads} / {dst_heads}"
            );
        }
        if dst_row + n_rows > dst_depth || src_row + n_rows > src_depth {
            bail!(
                "cache rows src {src_row}+{n_rows} / dst {dst_row}+{n_rows} outside depths {src_depth} / {dst_depth}"
            );
        }
        if n_rows == 0 || head_n == 0 {
            return Ok(());
        }
        let dst_slot_elems = dst_heads * dst_depth * dh;
        let src_slot_elems = src_heads * src_depth * dh;
        let len = n_rows * dh;
        for h in 0..head_n {
            let d = dst_slot * dst_slot_elems + (dst_head + h) * dst_depth * dh + dst_row * dh;
            let s = src_slot * src_slot_elems + (src_head + h) * src_depth * dh + src_row * dh;
            self.data[d..d + len].copy_from_slice(&src.data[s..s + len]);
        }
        Ok(())
    }

    /// Copy rows `[0, n_rows)` of dim-0 slot `src_slot` into `dst_slot`
    /// of the *same* rank-4 tensor, per head — the copy-on-write
    /// duplication of a shared KV block's occupied prefix onto a freshly
    /// owned block before a divergent append.
    pub fn copy_cache_rows_within(
        &mut self,
        dst_slot: usize,
        src_slot: usize,
        n_rows: usize,
    ) -> Result<()> {
        if self.dims.len() != 4 {
            bail!("within-tensor cache-row copy needs rank 4, got {:?}", self.dims);
        }
        let (heads, depth, dh) = (self.dims[1], self.dims[2], self.dims[3]);
        if dst_slot >= self.dims[0] || src_slot >= self.dims[0] {
            bail!(
                "within-tensor cache-row copy {src_slot}->{dst_slot} out of range ({} slots)",
                self.dims[0]
            );
        }
        if dst_slot == src_slot {
            bail!("within-tensor cache-row copy onto itself (slot {dst_slot})");
        }
        if n_rows > depth {
            bail!("within-tensor cache-row copy of {n_rows} rows exceeds depth {depth}");
        }
        if n_rows == 0 {
            return Ok(());
        }
        let slot_elems = heads * depth * dh;
        let len = n_rows * dh;
        for head in 0..heads {
            let s = src_slot * slot_elems + head * depth * dh;
            let d = dst_slot * slot_elems + head * depth * dh;
            self.data.copy_within(s..s + len, d);
        }
        Ok(())
    }

    /// Zero cache rows `[0, depth)` of `slot`, per head (depth-bounded
    /// evict for rank-4 KV caches). Rows at and beyond a slot's written
    /// depth never hold live data — decode reads `[0, pos]` and admission
    /// rewrites the whole slot — so evicting only the occupied prefix is
    /// equivalent to [`Self::clear_slot`] at a fraction of the traffic.
    pub fn clear_cache_rows(&mut self, slot: usize, depth_rows: usize) -> Result<()> {
        if self.dims.len() != 4 || slot >= self.dims[0] {
            bail!("clear_cache_rows {slot} out of range for shape {:?}", self.dims);
        }
        let (heads, depth, dh) = (self.dims[1], self.dims[2], self.dims[3]);
        if depth_rows > depth {
            bail!("clear_cache_rows depth {depth_rows} exceeds cache depth {depth}");
        }
        let slot_elems = heads * depth * dh;
        for head in 0..heads {
            let start = slot * slot_elems + head * depth * dh;
            for v in &mut self.data[start..start + depth_rows * dh] {
                *v = 0.0;
            }
        }
        Ok(())
    }
}

/// All tensors from a weights.bin, by name.
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    tensors: HashMap<String, Tensor>,
}

impl WeightStore {
    pub fn load(path: &std::path::Path) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightStore> {
        let mut r = bytes;
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("weights magic")?;
        if &magic != b"HXGW" {
            bail!("bad weights magic {magic:?}");
        }
        let version = read_u32(&mut r)?;
        if version != 1 {
            bail!("unsupported weights version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u16(&mut r)? as usize;
            let mut name_buf = vec![0u8; name_len];
            r.read_exact(&mut name_buf).context("tensor name")?;
            let name = String::from_utf8(name_buf).context("tensor name utf-8")?;
            let ndim = read_u8(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut r)? as usize);
            }
            let n: usize = dims.iter().product::<usize>().max(1);
            let mut data = vec![0f32; n];
            {
                let byte_len = n * 4;
                if r.len() < byte_len {
                    bail!("truncated tensor data for '{name}'");
                }
                let (head, rest) = r.split_at(byte_len);
                for (i, chunk) in head.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
                }
                r = rest;
            }
            tensors.insert(name, Tensor { dims, data });
        }
        if !r.is_empty() {
            bail!("{} trailing bytes after last tensor", r.len());
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing weight '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Insert (or replace) a tensor by name — synthetic models for
    /// benches and tests, built without a `weights.bin` round-trip.
    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    /// Sharded-weight name for a layer weight (`tp == 1` → unsharded name).
    pub fn shard_name(layer: usize, weight: &str, tp: usize, rank: usize) -> String {
        if tp == 1 {
            format!("layers.{layer}.{weight}")
        } else {
            format!("layers.{layer}.{weight}.tp{tp}.r{rank}")
        }
    }
}

fn read_u8(r: &mut &[u8]) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).context("read u8")?;
    Ok(b[0])
}

fn read_u16(r: &mut &[u8]) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b).context("read u16")?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        // two tensors: "a" [2,2] = 1..4; "b.c" [3] = 5,6,7
        let mut v = Vec::new();
        v.extend_from_slice(b"HXGW");
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(&1u16.to_le_bytes());
        v.extend_from_slice(b"a");
        v.push(2);
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(&2u32.to_le_bytes());
        for x in [1f32, 2.0, 3.0, 4.0] {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v.extend_from_slice(&3u16.to_le_bytes());
        v.extend_from_slice(b"b.c");
        v.push(1);
        v.extend_from_slice(&3u32.to_le_bytes());
        for x in [5f32, 6.0, 7.0] {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    #[test]
    fn parses_sample() {
        let ws = WeightStore::parse(&sample_bytes()).unwrap();
        assert_eq!(ws.len(), 2);
        let a = ws.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 2]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws.get("b.c").unwrap().data, vec![5.0, 6.0, 7.0]);
        assert!(ws.get("nope").is_err());
        assert_eq!(ws.names(), vec!["a", "b.c"]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_bytes();
        b[0] = b'X';
        assert!(WeightStore::parse(&b).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let mut b = sample_bytes();
        b[4] = 9;
        assert!(WeightStore::parse(&b).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let b = sample_bytes();
        assert!(WeightStore::parse(&b[..b.len() - 2]).is_err());
        let mut b2 = b.clone();
        b2.push(0);
        assert!(WeightStore::parse(&b2).is_err());
    }

    #[test]
    fn slot_insert_and_evict() {
        // dst: [3, 2] zeroed cache; src: [2, 2] prefill rows.
        let mut dst = Tensor { dims: vec![3, 2], data: vec![0.0; 6] };
        let src = Tensor { dims: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        dst.copy_slot_from(2, &src, 1).unwrap();
        assert_eq!(dst.data, vec![0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
        dst.copy_slot_from(0, &src, 0).unwrap();
        assert_eq!(dst.data, vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        dst.clear_slot(2).unwrap();
        assert_eq!(dst.data, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(dst.slots(), 3);
        assert_eq!(dst.slot_elements(), 2);
        // errors: out-of-range slots and mismatched trailing dims
        assert!(dst.copy_slot_from(3, &src, 0).is_err());
        assert!(dst.copy_slot_from(0, &src, 2).is_err());
        assert!(dst.clear_slot(3).is_err());
        let bad = Tensor { dims: vec![2, 3], data: vec![0.0; 6] };
        assert!(dst.copy_slot_from(0, &bad, 0).is_err());
    }

    #[test]
    fn cache_row_copy_and_clear_are_depth_bounded() {
        // Two-slot, two-head cache of depth 3, head_dim 2: slot layout is
        // [head0: r0 r1 r2][head1: r0 r1 r2], 12 elements per slot.
        let mut dst = Tensor { dims: vec![2, 2, 3, 2], data: vec![9.0; 24] };
        let src = Tensor { dims: vec![3, 2, 3, 2], data: (0..36).map(|i| i as f32).collect() };
        // Copy depth [0, 2) of src slot 1 into dst slot 0.
        dst.copy_cache_rows(0, &src, 1, 0..2).unwrap();
        // src slot 1 starts at 12: head0 rows 0..2 = 12..16, head1 = 18..22.
        assert_eq!(dst.data[0..4], [12.0, 13.0, 14.0, 15.0]);
        assert_eq!(dst.data[4..6], [9.0, 9.0], "row 2 of head 0 untouched");
        assert_eq!(dst.data[6..10], [18.0, 19.0, 20.0, 21.0]);
        assert_eq!(dst.data[10..12], [9.0, 9.0], "row 2 of head 1 untouched");
        assert_eq!(dst.data[12..], [9.0; 12], "slot 1 untouched");
        // Scatter-back shape: a single entry at depth 2.
        dst.copy_cache_rows(1, &src, 0, 2..3).unwrap();
        assert_eq!(dst.data[12..16], [9.0; 4]);
        assert_eq!(dst.data[16..18], [4.0, 5.0], "head 0 entry 2");
        assert_eq!(dst.data[22..24], [10.0, 11.0], "head 1 entry 2");
        // Empty range is a no-op.
        dst.copy_cache_rows(0, &src, 0, 1..1).unwrap();
        // Depth-bounded clear: zero [0, 1) of slot 0 only.
        dst.clear_cache_rows(0, 1).unwrap();
        assert_eq!(dst.data[0..2], [0.0, 0.0]);
        assert_eq!(dst.data[2..4], [14.0, 15.0], "row 1 survives a depth-1 clear");
        assert_eq!(dst.data[6..8], [0.0, 0.0], "head 1 row 0 cleared too");
        // Full-depth clear equals clear_slot.
        let mut a = dst.clone();
        let mut b = dst.clone();
        a.clear_cache_rows(1, 3).unwrap();
        b.clear_slot(1).unwrap();
        assert_eq!(a.data, b.data);
        // Errors: bad ranks, slots, and depths.
        assert!(dst.copy_cache_rows(2, &src, 0, 0..1).is_err());
        assert!(dst.copy_cache_rows(0, &src, 3, 0..1).is_err());
        assert!(dst.copy_cache_rows(0, &src, 0, 0..4).is_err());
        #[allow(clippy::reversed_empty_ranges)]
        {
            assert!(dst.copy_cache_rows(0, &src, 0, 2..1).is_err());
        }
        assert!(dst.clear_cache_rows(2, 1).is_err());
        assert!(dst.clear_cache_rows(0, 4).is_err());
        let rank3 = Tensor { dims: vec![2, 3, 2], data: vec![0.0; 12] };
        let mut r3 = rank3.clone();
        assert!(r3.copy_cache_rows(0, &rank3, 0, 0..1).is_err());
        assert!(r3.clear_cache_rows(0, 1).is_err());
    }

    #[test]
    fn cache_row_copy_between_different_depths() {
        // Block store: 3 blocks, 2 heads, block_tokens 2, head_dim 2
        // (8 elements per block). Step scratch: 2 slots of depth 4.
        let blocks = Tensor { dims: vec![3, 2, 2, 2], data: (0..24).map(|i| i as f32).collect() };
        let mut scratch = Tensor { dims: vec![2, 2, 4, 2], data: vec![-1.0; 32] };
        // Gather: block 1's full 2 rows land at scratch slot 0, row 2.
        scratch.copy_cache_rows_between(0, 2, &blocks, 1, 0, 2).unwrap();
        // block 1 starts at 8: head0 rows = 8..12, head1 rows = 12..16.
        assert_eq!(scratch.data[4..8], [8.0, 9.0, 10.0, 11.0], "head 0 rows 2..4");
        assert_eq!(scratch.data[0..4], [-1.0; 4], "head 0 rows 0..2 untouched");
        assert_eq!(scratch.data[12..16], [12.0, 13.0, 14.0, 15.0], "head 1 rows 2..4");
        assert_eq!(scratch.data[8..12], [-1.0; 4], "head 1 rows 0..2 untouched");
        assert_eq!(scratch.data[16..], [-1.0; 16], "slot 1 untouched");
        // Scatter: one row from scratch back into a block interior row.
        let mut store = blocks.clone();
        store.copy_cache_rows_between(2, 1, &scratch, 0, 3, 1).unwrap();
        assert_eq!(store.data[18..20], [10.0, 11.0], "head 0 row 1 of block 2");
        assert_eq!(store.data[22..24], [14.0, 15.0], "head 1 row 1 of block 2");
        assert_eq!(store.data[16..18], blocks.data[16..18], "row 0 untouched");
        // Zero rows is a no-op; bounds and head mismatches are surfaced.
        scratch.copy_cache_rows_between(0, 0, &blocks, 0, 0, 0).unwrap();
        assert!(scratch.copy_cache_rows_between(0, 3, &blocks, 0, 0, 2).is_err());
        assert!(scratch.copy_cache_rows_between(0, 0, &blocks, 0, 1, 2).is_err());
        assert!(scratch.copy_cache_rows_between(2, 0, &blocks, 0, 0, 1).is_err());
        assert!(scratch.copy_cache_rows_between(0, 0, &blocks, 3, 0, 1).is_err());
        let one_head = Tensor { dims: vec![1, 1, 2, 2], data: vec![0.0; 4] };
        assert!(scratch.copy_cache_rows_between(0, 0, &one_head, 0, 0, 1).is_err());
    }

    #[test]
    fn head_windowed_copy_bridges_different_head_counts() {
        // Shard store: 2 blocks × 2 heads × 2 rows × dh 2 (8 elems/block).
        // Segment: 1 slot × 4 heads × 3 rows × dh 2 — a full-layer KV
        // segment assembled from two 2-head shards.
        let shard = Tensor { dims: vec![2, 2, 2, 2], data: (0..16).map(|i| i as f32).collect() };
        let mut seg = Tensor { dims: vec![1, 4, 3, 2], data: vec![-1.0; 24] };
        // Export: shard block 1's 2 rows land at segment heads 2..4, row 0.
        seg.copy_cache_head_rows(0, 2, 0, &shard, 1, 0, 0, 2, 2).unwrap();
        // shard block 1 = elems 8..16: head0 rows 8..12, head1 rows 12..16.
        assert_eq!(seg.data[12..16], [8.0, 9.0, 10.0, 11.0], "segment head 2 rows 0..2");
        assert_eq!(seg.data[16..18], [-1.0, -1.0], "segment head 2 row 2 untouched");
        assert_eq!(seg.data[18..22], [12.0, 13.0, 14.0, 15.0], "segment head 3 rows 0..2");
        assert_eq!(seg.data[0..12], [-1.0; 12], "heads 0..2 untouched");
        // Import back into a differently-headed store: segment heads 2..4
        // row 1 → shard block 0 heads 0..2 row 0.
        let mut back = Tensor { dims: vec![2, 2, 2, 2], data: vec![0.0; 16] };
        back.copy_cache_head_rows(0, 0, 0, &seg, 0, 2, 1, 2, 1).unwrap();
        assert_eq!(back.data[0..2], [10.0, 11.0], "head 0 row 0");
        assert_eq!(back.data[4..6], [14.0, 15.0], "head 1 row 0");
        // Zero spans are no-ops; bounds violations are surfaced.
        seg.copy_cache_head_rows(0, 0, 0, &shard, 0, 0, 0, 0, 1).unwrap();
        seg.copy_cache_head_rows(0, 0, 0, &shard, 0, 0, 0, 1, 0).unwrap();
        assert!(seg.copy_cache_head_rows(0, 3, 0, &shard, 0, 0, 0, 2, 1).is_err(), "dst heads");
        assert!(seg.copy_cache_head_rows(0, 0, 0, &shard, 0, 1, 0, 2, 1).is_err(), "src heads");
        assert!(seg.copy_cache_head_rows(0, 0, 2, &shard, 0, 0, 0, 1, 2).is_err(), "dst depth");
        assert!(seg.copy_cache_head_rows(0, 0, 0, &shard, 0, 0, 1, 1, 2).is_err(), "src depth");
        assert!(seg.copy_cache_head_rows(1, 0, 0, &shard, 0, 0, 0, 1, 1).is_err(), "dst slot");
        assert!(seg.copy_cache_head_rows(0, 0, 0, &shard, 2, 0, 0, 1, 1).is_err(), "src slot");
        let dh3 = Tensor { dims: vec![1, 1, 1, 3], data: vec![0.0; 3] };
        assert!(seg.copy_cache_head_rows(0, 0, 0, &dh3, 0, 0, 0, 1, 1).is_err(), "dh mismatch");
    }

    #[test]
    fn cache_row_copy_within_duplicates_block_prefix() {
        // 3 blocks, 2 heads, block_tokens 2, head_dim 2.
        let mut t = Tensor { dims: vec![3, 2, 2, 2], data: (0..24).map(|i| i as f32).collect() };
        // COW: copy row 0 of block 0 into block 2, leave row 1 alone.
        t.copy_cache_rows_within(2, 0, 1).unwrap();
        assert_eq!(t.data[16..18], [0.0, 1.0], "head 0 row 0 copied");
        assert_eq!(t.data[18..20], [18.0, 19.0], "head 0 row 1 untouched");
        assert_eq!(t.data[20..22], [4.0, 5.0], "head 1 row 0 copied");
        assert_eq!(t.data[22..24], [22.0, 23.0], "head 1 row 1 untouched");
        assert_eq!(t.data[0..8], (0..8).map(|i| i as f32).collect::<Vec<_>>()[..], "source intact");
        t.copy_cache_rows_within(1, 0, 0).unwrap(); // no-op
        assert_eq!(t.data[8..10], [8.0, 9.0]);
        assert!(t.copy_cache_rows_within(0, 0, 1).is_err(), "self-copy rejected");
        assert!(t.copy_cache_rows_within(3, 0, 1).is_err());
        assert!(t.copy_cache_rows_within(0, 3, 1).is_err());
        assert!(t.copy_cache_rows_within(0, 1, 3).is_err(), "depth exceeded");
        let mut r3 = Tensor { dims: vec![2, 3, 2], data: vec![0.0; 12] };
        assert!(r3.copy_cache_rows_within(0, 1, 1).is_err());
    }

    #[test]
    fn insert_adds_tensor() {
        let mut ws = WeightStore::default();
        assert!(ws.is_empty());
        ws.insert("w", Tensor { dims: vec![2], data: vec![1.0, 2.0] });
        assert_eq!(ws.get("w").unwrap().data, vec![1.0, 2.0]);
        assert_eq!(ws.len(), 1);
    }

    #[test]
    fn shard_names() {
        assert_eq!(WeightStore::shard_name(3, "wq", 1, 0), "layers.3.wq");
        assert_eq!(WeightStore::shard_name(3, "wq", 2, 1), "layers.3.wq.tp2.r1");
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights.bin");
        if !path.exists() {
            return; // artifacts not built in this environment
        }
        let ws = WeightStore::load(&path).unwrap();
        // demo model: embed [256,128], shards for tp 2 and 4
        let e = ws.get("embed").unwrap();
        assert_eq!(e.dims, vec![256, 128]);
        assert!(ws.contains("layers.0.wq.tp2.r0"));
        assert!(ws.contains("layers.5.w2.tp4.r3"));
        let wq = ws.get("layers.0.wq.tp2.r0").unwrap();
        assert_eq!(wq.dims, vec![128, 64]);
    }
}
