//! Pure-Rust reference execution backend.
//!
//! Mirrors the numerics of `python/compile/kernels/ref.py` (naive causal
//! softmax attention, RMSNorm, ReLU MLP) over the manifest's weight
//! layout, so the pipeline coordinator, batcher, and service layer can be
//! exercised end-to-end in plain `cargo test` with zero native
//! dependencies. Stage names follow the AOT artifact grammar
//! (`attn_prefill_tp{T}_b{B}`, `embed_decode_b{B}`, …); no `.hlo.txt`
//! files are read — only `manifest.json` + `weights.bin`.
//!
//! Checked against golden values emitted by
//! `python/compile/make_ref_fixture.py` (see `tests/reference_parity.rs`).

use std::cell::Cell;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{ExecutionBackend, InputArg};
use super::manifest::Manifest;
use super::weights::{Tensor, WeightStore};

const RMSNORM_EPS: f32 = 1e-6;

/// Pure-Rust stage executor over a manifest + weight store.
pub struct ReferenceBackend {
    manifest: Manifest,
    weights: Arc<WeightStore>,
    exec_count: Cell<usize>,
}

impl ReferenceBackend {
    /// Load manifest + weights from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ReferenceBackend> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&dir.join("weights.bin"))?);
        Ok(Self::with_weights(manifest, weights))
    }

    /// Create a backend re-using an already-parsed weight store.
    pub fn with_weights(manifest: Manifest, weights: Arc<WeightStore>) -> ReferenceBackend {
        ReferenceBackend { manifest, weights, exec_count: Cell::new(0) }
    }

    fn tensor_arg<'t>(&'t self, a: &'t InputArg<'t>, what: &str) -> Result<&'t Tensor> {
        match a {
            InputArg::F32(t) => Ok(*t),
            InputArg::Weight(n) => self.weights.get(n),
            _ => bail!("{what}: expected an f32 tensor or weight"),
        }
    }

    // ---- stage implementations -----------------------------------------

    fn run_embed(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 2, "embed")?;
        let (tokens, dims) = tokens_arg(&inputs[0], "embed tokens")?;
        let emb = self.tensor_arg(&inputs[1], "embed table")?;
        let m = &self.manifest.model;
        if emb.dims != vec![m.vocab, m.hidden] {
            bail!("embed table has shape {:?}, expected [{}, {}]", emb.dims, m.vocab, m.hidden);
        }
        if dims.len() != 2 || dims[0] != st.bucket {
            bail!("embed tokens shape {dims:?} does not match bucket {}", st.bucket);
        }
        let s = dims[1];
        if tokens.len() != st.bucket * s {
            bail!("embed: {} tokens for shape {dims:?}", tokens.len());
        }
        let h = m.hidden;
        let mut out = vec![0f32; tokens.len() * h];
        for (row, &t) in tokens.iter().enumerate() {
            // jnp.take clips out-of-range indices under jit; mirror that.
            let idx = (t.max(0) as usize).min(m.vocab - 1);
            out[row * h..(row + 1) * h].copy_from_slice(&emb.data[idx * h..(idx + 1) * h]);
        }
        Ok(vec![Tensor { dims: vec![st.bucket, s, h], data: out }])
    }

    fn run_lm_head(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 3, "lm_head")?;
        let x = self.tensor_arg(&inputs[0], "lm_head x")?;
        let ln = self.tensor_arg(&inputs[1], "final_ln")?;
        let w = self.tensor_arg(&inputs[2], "lm_head weight")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "lm_head x")?;
        check_bucket(b, st)?;
        if s == 0 {
            bail!("lm_head input has zero sequence length");
        }
        if h != m.hidden {
            bail!("lm_head x hidden {h} != model hidden {}", m.hidden);
        }
        if w.dims != vec![h, m.vocab] {
            bail!("lm_head weight has shape {:?}, expected [{h}, {}]", w.dims, m.vocab);
        }
        // Last position per batch row, RMSNorm, then project to vocab.
        let mut last = vec![0f32; b * h];
        for bi in 0..b {
            let src = (bi * s + (s - 1)) * h;
            last[bi * h..(bi + 1) * h].copy_from_slice(&x.data[src..src + h]);
        }
        let xn = rmsnorm_rows(&last, h, &ln.data)?;
        let logits = matmul(&xn, b, h, w, "lm_head")?;
        Ok(vec![Tensor { dims: vec![b, m.vocab], data: logits }])
    }

    fn run_attn_prefill(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 6, "attn_prefill")?;
        let x = self.tensor_arg(&inputs[0], "attn x")?;
        let ln = self.tensor_arg(&inputs[1], "ln1")?;
        let wq = self.tensor_arg(&inputs[2], "wq")?;
        let wk = self.tensor_arg(&inputs[3], "wk")?;
        let wv = self.tensor_arg(&inputs[4], "wv")?;
        let wo = self.tensor_arg(&inputs[5], "wo")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "attn x")?;
        check_bucket(b, st)?;
        if s == 0 || s > m.max_seq {
            bail!("attn_prefill sequence length {s} outside [1, {}]", m.max_seq);
        }
        let shard = self.shard_dims(st.tp, h, wq, wk, wv, wo)?;
        let (nhs, dh, hs) = (shard.nhs, shard.dh, shard.hs);

        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let q = matmul(&xn, b * s, h, wq, "wq")?;
        let k = matmul(&xn, b * s, h, wk, "wk")?;
        let v = matmul(&xn, b * s, h, wv, "wv")?;

        // Causal softmax attention per (batch row, head); the per-shard
        // layout is [row, head*dh + d] with row = bi*s + position.
        let mut merged = vec![0f32; b * s * hs];
        let scale = 1.0 / (dh as f32).sqrt();
        for bi in 0..b {
            for head in 0..nhs {
                let off = head * dh;
                for i in 0..s {
                    let qrow = (bi * s + i) * hs + off;
                    let mut scores = Vec::with_capacity(i + 1);
                    let mut max_s = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let krow = (bi * s + j) * hs + off;
                        let mut dot = 0f32;
                        for d in 0..dh {
                            dot += q[qrow + d] * k[krow + d];
                        }
                        let sc = dot * scale;
                        if sc > max_s {
                            max_s = sc;
                        }
                        scores.push(sc);
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - max_s).exp();
                        denom += *sc;
                    }
                    for d in 0..dh {
                        let mut acc = 0f32;
                        for (j, p) in scores.iter().enumerate() {
                            acc += p * v[(bi * s + j) * hs + off + d];
                        }
                        merged[qrow + d] = acc / denom;
                    }
                }
            }
        }
        let partial = matmul(&merged, b * s, hs, wo, "wo")?;

        // Zero-padded shard caches [b, nhs, s_max, dh], filled in [0, s).
        let s_max = m.max_seq;
        let mut kc = vec![0f32; b * nhs * s_max * dh];
        let mut vc = vec![0f32; b * nhs * s_max * dh];
        for bi in 0..b {
            for head in 0..nhs {
                for j in 0..s {
                    let dst = ((bi * nhs + head) * s_max + j) * dh;
                    let src = (bi * s + j) * hs + head * dh;
                    kc[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                    vc[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                }
            }
        }
        let cache_dims = vec![b, nhs, s_max, dh];
        Ok(vec![
            Tensor { dims: vec![b, s, h], data: partial },
            Tensor { dims: cache_dims.clone(), data: kc },
            Tensor { dims: cache_dims, data: vc },
        ])
    }

    fn run_attn_decode(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 9, "attn_decode")?;
        let x = self.tensor_arg(&inputs[0], "attn x")?;
        let kc_in = self.tensor_arg(&inputs[1], "k_cache")?;
        let vc_in = self.tensor_arg(&inputs[2], "v_cache")?;
        let ln = self.tensor_arg(&inputs[4], "ln1")?;
        let wq = self.tensor_arg(&inputs[5], "wq")?;
        let wk = self.tensor_arg(&inputs[6], "wk")?;
        let wv = self.tensor_arg(&inputs[7], "wv")?;
        let wo = self.tensor_arg(&inputs[8], "wo")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "attn x")?;
        check_bucket(b, st)?;
        if s != 1 {
            bail!("attn_decode expects a single-token input, got s={s}");
        }
        let shard = self.shard_dims(st.tp, h, wq, wk, wv, wo)?;
        let (nhs, dh, hs) = (shard.nhs, shard.dh, shard.hs);
        let s_max = m.max_seq;
        let cache_dims = vec![b, nhs, s_max, dh];
        if kc_in.dims != cache_dims || vc_in.dims != cache_dims {
            bail!(
                "decode caches have shapes {:?}/{:?}, expected {cache_dims:?}",
                kc_in.dims,
                vc_in.dims
            );
        }
        // Decode positions: a batch-wide scalar (uniform batches, the shape
        // the AOT artifacts compile) or a per-row `[b]` int32 vector — what
        // continuous batching needs when co-batched rows sit at different
        // sequence depths.
        let positions: Vec<usize> = match &inputs[3] {
            InputArg::ScalarI32(p) => vec![*p; b],
            InputArg::I32(data, dims) => {
                if data.len() != b || dims.first() != Some(&b) {
                    bail!(
                        "decode positions: {} values (dims {dims:?}) for batch {b}",
                        data.len()
                    );
                }
                data.to_vec()
            }
            _ => bail!("pos: expected an int32 scalar or per-row int32 vector"),
        }
        .into_iter()
        .map(|p| {
            if p < 0 || p as usize >= s_max {
                bail!("decode position {p} outside cache of length {s_max}");
            }
            Ok(p as usize)
        })
        .collect::<Result<_>>()?;

        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let q = matmul(&xn, b, h, wq, "wq")?;
        let k_new = matmul(&xn, b, h, wk, "wk")?;
        let v_new = matmul(&xn, b, h, wv, "wv")?;

        // Functionally-updated caches: write each row's token at its own
        // position.
        let mut kc = kc_in.data.clone();
        let mut vc = vc_in.data.clone();
        for bi in 0..b {
            for head in 0..nhs {
                let dst = ((bi * nhs + head) * s_max + positions[bi]) * dh;
                let src = bi * hs + head * dh;
                kc[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
                vc[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
            }
        }

        // Single-token attention over each row's first pos+1 cache entries.
        let mut merged = vec![0f32; b * hs];
        let scale = 1.0 / (dh as f32).sqrt();
        for bi in 0..b {
            let pos = positions[bi];
            for head in 0..nhs {
                let qrow = bi * hs + head * dh;
                let base = (bi * nhs + head) * s_max;
                let mut scores = Vec::with_capacity(pos + 1);
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=pos {
                    let krow = (base + j) * dh;
                    let mut dot = 0f32;
                    for d in 0..dh {
                        dot += q[qrow + d] * kc[krow + d];
                    }
                    let sc = dot * scale;
                    if sc > max_s {
                        max_s = sc;
                    }
                    scores.push(sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max_s).exp();
                    denom += *sc;
                }
                for d in 0..dh {
                    let mut acc = 0f32;
                    for (j, p) in scores.iter().enumerate() {
                        acc += p * vc[(base + j) * dh + d];
                    }
                    merged[qrow + d] = acc / denom;
                }
            }
        }
        let partial = matmul(&merged, b, hs, wo, "wo")?;
        Ok(vec![
            Tensor { dims: vec![b, 1, h], data: partial },
            Tensor { dims: cache_dims.clone(), data: kc },
            Tensor { dims: cache_dims, data: vc },
        ])
    }

    fn run_mlp(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 4, "mlp")?;
        let x = self.tensor_arg(&inputs[0], "mlp x")?;
        let ln = self.tensor_arg(&inputs[1], "ln2")?;
        let w1 = self.tensor_arg(&inputs[2], "w1")?;
        let w2 = self.tensor_arg(&inputs[3], "w2")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "mlp x")?;
        check_bucket(b, st)?;
        if h != m.hidden {
            bail!("mlp x hidden {h} != model hidden {}", m.hidden);
        }
        let fs = m.ffn / st.tp;
        if w1.dims != vec![h, fs] || w2.dims != vec![fs, h] {
            bail!(
                "mlp shard weights have shapes {:?}/{:?}, expected [{h}, {fs}]/[{fs}, {h}]",
                w1.dims,
                w2.dims
            );
        }
        let rows = b * s;
        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let mut hidden = matmul(&xn, rows, h, w1, "w1")?;
        for v in hidden.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let out = matmul(&hidden, rows, fs, w2, "w2")?;
        Ok(vec![Tensor { dims: vec![b, s, h], data: out }])
    }

    /// Validate shard projection widths against the stage's TP degree.
    fn shard_dims(
        &self,
        tp: usize,
        h: usize,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
    ) -> Result<ShardDims> {
        let m = &self.manifest.model;
        if h != m.hidden {
            bail!("stage input hidden {h} != model hidden {}", m.hidden);
        }
        if tp == 0 || m.heads % tp != 0 {
            bail!("tp={tp} does not divide {} heads", m.heads);
        }
        let nhs = m.heads / tp;
        let dh = m.head_dim;
        let hs = nhs * dh;
        for (name, w) in [("wq", wq), ("wk", wk), ("wv", wv)] {
            if w.dims != vec![h, hs] {
                bail!("{name} shard has shape {:?}, expected [{h}, {hs}]", w.dims);
            }
        }
        if wo.dims != vec![hs, h] {
            bail!("wo shard has shape {:?}, expected [{hs}, {h}]", wo.dims);
        }
        Ok(ShardDims { nhs, dh, hs })
    }
}

struct ShardDims {
    nhs: usize,
    dh: usize,
    hs: usize,
}

impl ExecutionBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn weights(&self) -> &Arc<WeightStore> {
        &self.weights
    }

    fn supports_rowwise_decode_positions(&self) -> bool {
        true
    }

    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        let Some(st) = StageName::parse(artifact) else {
            bail!("reference backend cannot execute artifact '{artifact}' (unknown stage name)");
        };
        if !self.manifest.batch_buckets.contains(&st.bucket) {
            bail!(
                "artifact '{artifact}': bucket {} not in manifest {:?}",
                st.bucket,
                self.manifest.batch_buckets
            );
        }
        if !self.manifest.tp_degrees.contains(&st.tp) {
            bail!(
                "artifact '{artifact}': tp {} not in manifest {:?}",
                st.tp,
                self.manifest.tp_degrees
            );
        }
        self.exec_count.set(self.exec_count.get() + 1);
        match (st.op, st.prefill) {
            (Op::Embed, _) => self.run_embed(&st, inputs),
            (Op::LmHead, _) => self.run_lm_head(&st, inputs),
            (Op::Attn, true) => self.run_attn_prefill(&st, inputs),
            (Op::Attn, false) => self.run_attn_decode(&st, inputs),
            (Op::Mlp, _) => self.run_mlp(&st, inputs),
        }
    }

    fn exec_count(&self) -> usize {
        self.exec_count.get()
    }
}

// ---- stage-name grammar ----------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Embed,
    LmHead,
    Attn,
    Mlp,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct StageName {
    op: Op,
    prefill: bool,
    tp: usize,
    bucket: usize,
}

impl StageName {
    /// Parse `{op}_{phase}[_tp{T}]_b{B}` artifact names.
    fn parse(name: &str) -> Option<StageName> {
        let (op, rest) = if let Some(r) = name.strip_prefix("embed_") {
            (Op::Embed, r)
        } else if let Some(r) = name.strip_prefix("lm_head_") {
            (Op::LmHead, r)
        } else if let Some(r) = name.strip_prefix("attn_") {
            (Op::Attn, r)
        } else if let Some(r) = name.strip_prefix("mlp_") {
            (Op::Mlp, r)
        } else {
            return None;
        };
        let (prefill, rest) = if let Some(r) = rest.strip_prefix("prefill_") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("decode_") {
            (false, r)
        } else {
            return None;
        };
        let (tp, rest) = match rest.strip_prefix("tp") {
            Some(r) => {
                let (digits, r2) = r.split_once('_')?;
                (digits.parse().ok()?, r2)
            }
            None => (1, rest),
        };
        let bucket = rest.strip_prefix('b')?.parse().ok()?;
        Some(StageName { op, prefill, tp, bucket })
    }
}

// ---- numerics helpers ------------------------------------------------------

/// RMSNorm over rows of width `h` (ref.py `rmsnorm_ref`).
fn rmsnorm_rows(x: &[f32], h: usize, scale: &[f32]) -> Result<Vec<f32>> {
    if scale.len() != h {
        bail!("rmsnorm scale has {} elements, rows have {h}", scale.len());
    }
    if x.len() % h != 0 {
        bail!("rmsnorm input of {} elements is not a multiple of {h}", x.len());
    }
    let mut out = vec![0f32; x.len()];
    for (orow, row) in out.chunks_exact_mut(h).zip(x.chunks_exact(h)) {
        let mut ss = 0f32;
        for &v in row {
            ss += v * v;
        }
        let denom = (ss / h as f32 + RMSNORM_EPS).sqrt();
        for i in 0..h {
            orow[i] = row[i] * scale[i] / denom;
        }
    }
    Ok(out)
}

/// `[rows, k] @ w[k, n]` row-major matmul.
fn matmul(x: &[f32], rows: usize, k: usize, w: &Tensor, what: &str) -> Result<Vec<f32>> {
    if w.dims.len() != 2 || w.dims[0] != k {
        bail!("{what}: weight shape {:?} incompatible with inner dim {k}", w.dims);
    }
    if x.len() != rows * k {
        bail!("{what}: input of {} elements is not [{rows}, {k}]", x.len());
    }
    let n = w.dims[1];
    let mut out = vec![0f32; rows * n];
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (i, &xv) in xrow.iter().enumerate() {
            let wrow = &w.data[i * n..(i + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    Ok(out)
}

fn dims3(t: &Tensor, what: &str) -> Result<(usize, usize, usize)> {
    if t.dims.len() != 3 {
        bail!("{what}: expected a rank-3 tensor, got {:?}", t.dims);
    }
    Ok((t.dims[0], t.dims[1], t.dims[2]))
}

fn check_bucket(b: usize, st: &StageName) -> Result<()> {
    if b != st.bucket {
        bail!("input batch {b} does not match artifact bucket {}", st.bucket);
    }
    Ok(())
}

fn expect_inputs(inputs: &[InputArg<'_>], n: usize, what: &str) -> Result<()> {
    if inputs.len() != n {
        bail!("{what} expects {n} inputs, got {}", inputs.len());
    }
    Ok(())
}

fn tokens_arg<'t>(a: &'t InputArg<'t>, what: &str) -> Result<(&'t [i32], &'t [usize])> {
    match a {
        InputArg::I32(data, dims) => Ok((*data, dims.as_slice())),
        _ => bail!("{what}: expected int32 tokens"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_parse() {
        assert_eq!(
            StageName::parse("attn_prefill_tp2_b4"),
            Some(StageName { op: Op::Attn, prefill: true, tp: 2, bucket: 4 })
        );
        assert_eq!(
            StageName::parse("embed_decode_b1"),
            Some(StageName { op: Op::Embed, prefill: false, tp: 1, bucket: 1 })
        );
        assert_eq!(
            StageName::parse("lm_head_prefill_b2"),
            Some(StageName { op: Op::LmHead, prefill: true, tp: 1, bucket: 2 })
        );
        assert_eq!(
            StageName::parse("mlp_decode_tp4_b1"),
            Some(StageName { op: Op::Mlp, prefill: false, tp: 4, bucket: 1 })
        );
        assert_eq!(StageName::parse("full_prefill_b1"), None);
        assert_eq!(StageName::parse("attn_warmup_tp2_b1"), None);
        assert_eq!(StageName::parse("attn_prefill_tpx_b1"), None);
    }

    #[test]
    fn rmsnorm_matches_formula() {
        // Constant row of 2.0 with unit scale: 2/sqrt(4 + eps) ≈ 1.
        let out = rmsnorm_rows(&[2.0, 2.0, 2.0, 2.0], 4, &[1.0; 4]).unwrap();
        for v in out {
            assert!((v - 1.0).abs() < 1e-5, "{v}");
        }
        assert!(rmsnorm_rows(&[1.0, 2.0], 3, &[1.0; 3]).is_err());
    }

    #[test]
    fn matmul_small() {
        let w = Tensor { dims: vec![2, 3], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        // [1, 2] @ w = [1+8, 2+10, 3+12]
        let out = matmul(&[1.0, 2.0], 1, 2, &w, "t").unwrap();
        assert_eq!(out, vec![9.0, 12.0, 15.0]);
        assert!(matmul(&[1.0], 1, 2, &w, "t").is_err());
    }

    #[test]
    fn softmax_attention_single_position_returns_v() {
        // With one position the softmax weight is exactly 1, so attention
        // output == v regardless of q/k. Exercise via run_attn_prefill on
        // a minimal hand-built model (h=2, heads=1).
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest, Arc::new(WeightStore::default()));
        let x = Tensor { dims: vec![1, 1, 2], data: vec![0.5, -0.25] };
        let ln = Tensor { dims: vec![2], data: vec![1.0, 1.0] };
        let eye = Tensor { dims: vec![2, 2], data: vec![1.0, 0.0, 0.0, 1.0] };
        let outs = be
            .execute(
                "attn_prefill_tp1_b1",
                &[
                    InputArg::F32(&x),
                    InputArg::F32(&ln),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 3);
        // partial == v == rmsnorm(x) when every projection is identity.
        let xn = rmsnorm_rows(&x.data, 2, &ln.data).unwrap();
        for (a, b) in outs[0].data.iter().zip(&xn) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // caches zero-padded to max_seq.
        assert_eq!(outs[1].dims, vec![1, 1, 2, 2]);
        assert_eq!(&outs[1].data[0..2], &xn[..]);
        assert_eq!(&outs[1].data[2..4], &[0.0, 0.0]);
        assert_eq!(be.exec_count(), 1);
    }

    #[test]
    fn unknown_artifacts_rejected() {
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest, Arc::new(WeightStore::default()));
        assert!(be.execute("full_prefill_b1", &[]).is_err());
        assert!(be.execute("attn_prefill_tp2_b1", &[]).is_err()); // tp 2 absent
        assert!(be.execute("embed_prefill_b4", &[]).is_err()); // bucket 4 absent
    }
}
