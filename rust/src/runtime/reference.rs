//! Pure-Rust reference execution backend.
//!
//! Mirrors the numerics of `python/compile/kernels/ref.py` (naive causal
//! softmax attention, RMSNorm, ReLU MLP) over the manifest's weight
//! layout, so the pipeline coordinator, batcher, and service layer can be
//! exercised end-to-end in plain `cargo test` with zero native
//! dependencies. Stage names follow the AOT artifact grammar
//! (`attn_prefill_tp{T}_b{B}`, `embed_decode_b{B}`, …); no `.hlo.txt`
//! files are read — only `manifest.json` + `weights.bin`.
//!
//! The backend is `Sync` (the execution counter is atomic, everything
//! else is read-only), so the pipeline can fan TP shard executions out
//! over scoped threads ([`ExecutionBackend::sync_view`]), and it serves
//! the decode hot path through the in-place cache entry point
//! ([`ExecutionBackend::execute_attn_decode_inplace`]) — no cache clones
//! on the per-token path. The value-passing [`ExecutionBackend::execute`]
//! contract (caches in, updated caches out) is retained for artifact
//! parity; [`FunctionalBackend`] pins exactly those seed semantics for
//! parity tests and the `benches/decode.rs` baseline.
//!
//! Checked against golden values emitted by
//! `python/compile/make_ref_fixture.py` (see `tests/reference_parity.rs`).

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{AttnShardWeights, DecodePositions, ExecutionBackend, InputArg};
use super::manifest::Manifest;
use super::weights::{Tensor, WeightStore};

const RMSNORM_EPS: f32 = 1e-6;

/// Pure-Rust stage executor over a manifest + weight store.
pub struct ReferenceBackend {
    manifest: Manifest,
    weights: Arc<WeightStore>,
    exec_count: AtomicUsize,
}

impl ReferenceBackend {
    /// Load manifest + weights from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ReferenceBackend> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&dir.join("weights.bin"))?);
        Ok(Self::with_weights(manifest, weights))
    }

    /// Create a backend re-using an already-parsed weight store.
    pub fn with_weights(manifest: Manifest, weights: Arc<WeightStore>) -> ReferenceBackend {
        ReferenceBackend { manifest, weights, exec_count: AtomicUsize::new(0) }
    }

    fn tensor_arg<'t>(&'t self, a: &'t InputArg<'t>, what: &str) -> Result<&'t Tensor> {
        match a {
            InputArg::F32(t) => Ok(*t),
            InputArg::Weight(n) => self.weights.get(n),
            _ => bail!("{what}: expected an f32 tensor or weight"),
        }
    }

    /// Parse an artifact name and check it against the manifest's bucket
    /// and TP catalogs.
    fn validate_stage(&self, artifact: &str) -> Result<StageName> {
        let Some(st) = StageName::parse(artifact) else {
            bail!("reference backend cannot execute artifact '{artifact}' (unknown stage name)");
        };
        if !self.manifest.batch_buckets.contains(&st.bucket) {
            bail!(
                "artifact '{artifact}': bucket {} not in manifest {:?}",
                st.bucket,
                self.manifest.batch_buckets
            );
        }
        if !self.manifest.tp_degrees.contains(&st.tp) {
            bail!(
                "artifact '{artifact}': tp {} not in manifest {:?}",
                st.tp,
                self.manifest.tp_degrees
            );
        }
        Ok(st)
    }

    // ---- stage implementations -----------------------------------------

    fn run_embed(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 2, "embed")?;
        let (tokens, dims) = tokens_arg(&inputs[0], "embed tokens")?;
        let emb = self.tensor_arg(&inputs[1], "embed table")?;
        let m = &self.manifest.model;
        if emb.dims != vec![m.vocab, m.hidden] {
            bail!("embed table has shape {:?}, expected [{}, {}]", emb.dims, m.vocab, m.hidden);
        }
        if dims.len() != 2 || dims[0] != st.bucket {
            bail!("embed tokens shape {dims:?} does not match bucket {}", st.bucket);
        }
        let s = dims[1];
        if tokens.len() != st.bucket * s {
            bail!("embed: {} tokens for shape {dims:?}", tokens.len());
        }
        let h = m.hidden;
        let mut out = vec![0f32; tokens.len() * h];
        for (row, &t) in tokens.iter().enumerate() {
            // jnp.take clips out-of-range indices under jit; mirror that.
            let idx = (t.max(0) as usize).min(m.vocab - 1);
            out[row * h..(row + 1) * h].copy_from_slice(&emb.data[idx * h..(idx + 1) * h]);
        }
        Ok(vec![Tensor { dims: vec![st.bucket, s, h], data: out }])
    }

    fn run_lm_head(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 3, "lm_head")?;
        let x = self.tensor_arg(&inputs[0], "lm_head x")?;
        let ln = self.tensor_arg(&inputs[1], "final_ln")?;
        let w = self.tensor_arg(&inputs[2], "lm_head weight")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "lm_head x")?;
        check_bucket(b, st)?;
        if s == 0 {
            bail!("lm_head input has zero sequence length");
        }
        if h != m.hidden {
            bail!("lm_head x hidden {h} != model hidden {}", m.hidden);
        }
        if w.dims != vec![h, m.vocab] {
            bail!("lm_head weight has shape {:?}, expected [{h}, {}]", w.dims, m.vocab);
        }
        // Last position per batch row, RMSNorm, then project to vocab.
        let mut last = vec![0f32; b * h];
        for bi in 0..b {
            let src = (bi * s + (s - 1)) * h;
            last[bi * h..(bi + 1) * h].copy_from_slice(&x.data[src..src + h]);
        }
        let xn = rmsnorm_rows(&last, h, &ln.data)?;
        let logits = matmul(&xn, b, h, w, "lm_head")?;
        Ok(vec![Tensor { dims: vec![b, m.vocab], data: logits }])
    }

    fn run_attn_prefill(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 6, "attn_prefill")?;
        let x = self.tensor_arg(&inputs[0], "attn x")?;
        let ln = self.tensor_arg(&inputs[1], "ln1")?;
        let wq = self.tensor_arg(&inputs[2], "wq")?;
        let wk = self.tensor_arg(&inputs[3], "wk")?;
        let wv = self.tensor_arg(&inputs[4], "wv")?;
        let wo = self.tensor_arg(&inputs[5], "wo")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "attn x")?;
        check_bucket(b, st)?;
        if s == 0 || s > m.max_seq {
            bail!("attn_prefill sequence length {s} outside [1, {}]", m.max_seq);
        }
        let shard = self.shard_dims(st.tp, h, wq, wk, wv, wo)?;
        let (nhs, dh, hs) = (shard.nhs, shard.dh, shard.hs);

        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let q = matmul(&xn, b * s, h, wq, "wq")?;
        let k = matmul(&xn, b * s, h, wk, "wk")?;
        let v = matmul(&xn, b * s, h, wv, "wv")?;

        // Causal softmax attention per (batch row, head); the per-shard
        // layout is [row, head*dh + d] with row = bi*s + position.
        let mut merged = vec![0f32; b * s * hs];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores: Vec<f32> = Vec::with_capacity(s);
        for bi in 0..b {
            for head in 0..nhs {
                let off = head * dh;
                for i in 0..s {
                    let qrow = (bi * s + i) * hs + off;
                    scores.clear();
                    let mut max_s = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let krow = (bi * s + j) * hs + off;
                        let mut dot = 0f32;
                        for d in 0..dh {
                            dot += q[qrow + d] * k[krow + d];
                        }
                        let sc = dot * scale;
                        if sc > max_s {
                            max_s = sc;
                        }
                        scores.push(sc);
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - max_s).exp();
                        denom += *sc;
                    }
                    for d in 0..dh {
                        let mut acc = 0f32;
                        for (j, p) in scores.iter().enumerate() {
                            acc += p * v[(bi * s + j) * hs + off + d];
                        }
                        merged[qrow + d] = acc / denom;
                    }
                }
            }
        }
        let partial = matmul(&merged, b * s, hs, wo, "wo")?;

        // Zero-padded shard caches [b, nhs, s_max, dh], filled in [0, s).
        let s_max = m.max_seq;
        let mut kc = vec![0f32; b * nhs * s_max * dh];
        let mut vc = vec![0f32; b * nhs * s_max * dh];
        for bi in 0..b {
            for head in 0..nhs {
                for j in 0..s {
                    let dst = ((bi * nhs + head) * s_max + j) * dh;
                    let src = (bi * s + j) * hs + head * dh;
                    kc[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                    vc[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                }
            }
        }
        let cache_dims = vec![b, nhs, s_max, dh];
        Ok(vec![
            Tensor { dims: vec![b, s, h], data: partial },
            Tensor { dims: cache_dims.clone(), data: kc },
            Tensor { dims: cache_dims, data: vc },
        ])
    }

    /// The functional decode contract (`execute` path): caches flow
    /// through as values, so the updated pair is materialized as fresh
    /// tensors. The serving hot path avoids this entirely via
    /// [`ExecutionBackend::execute_attn_decode_inplace`].
    fn run_attn_decode(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 9, "attn_decode")?;
        let x = self.tensor_arg(&inputs[0], "attn x")?;
        let kc_in = self.tensor_arg(&inputs[1], "k_cache")?;
        let vc_in = self.tensor_arg(&inputs[2], "v_cache")?;
        let ln = self.tensor_arg(&inputs[4], "ln1")?;
        let wq = self.tensor_arg(&inputs[5], "wq")?;
        let wk = self.tensor_arg(&inputs[6], "wk")?;
        let wv = self.tensor_arg(&inputs[7], "wv")?;
        let wo = self.tensor_arg(&inputs[8], "wo")?;
        let (b, _, _) = dims3(x, "attn x")?;
        // Decode positions: a batch-wide scalar (uniform batches, the shape
        // the AOT artifacts compile) or a per-row `[b]` int32 vector — what
        // continuous batching needs when co-batched rows sit at different
        // sequence depths.
        let positions = match &inputs[3] {
            InputArg::ScalarI32(p) => DecodePositions::Scalar(*p),
            InputArg::I32(data, dims) => {
                if data.len() != b || dims.first() != Some(&b) {
                    bail!(
                        "decode positions: {} values (dims {dims:?}) for batch {b}",
                        data.len()
                    );
                }
                DecodePositions::PerRow(data)
            }
            _ => bail!("pos: expected an int32 scalar or per-row int32 vector"),
        };
        let mut kc = kc_in.clone();
        let mut vc = vc_in.clone();
        let partial = self.attn_decode_core(st, x, &mut kc, &mut vc, positions, ln, wq, wk, wv, wo)?;
        Ok(vec![partial, kc, vc])
    }

    /// Decode-attention kernel shared by the functional and in-place
    /// entry points: writes each row's new K/V slice into the caches at
    /// its own position and attends over that row's `[0, pos]` entries,
    /// reading the caches where they live.
    #[allow(clippy::too_many_arguments)]
    fn attn_decode_core(
        &self,
        st: &StageName,
        x: &Tensor,
        kc: &mut Tensor,
        vc: &mut Tensor,
        positions: DecodePositions<'_>,
        ln: &Tensor,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
    ) -> Result<Tensor> {
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "attn x")?;
        check_bucket(b, st)?;
        if s != 1 {
            bail!("attn_decode expects a single-token input, got s={s}");
        }
        let shard = self.shard_dims(st.tp, h, wq, wk, wv, wo)?;
        let (nhs, dh, hs) = (shard.nhs, shard.dh, shard.hs);
        let s_max = m.max_seq;
        let cache_dims = vec![b, nhs, s_max, dh];
        if kc.dims != cache_dims || vc.dims != cache_dims {
            bail!(
                "decode caches have shapes {:?}/{:?}, expected {cache_dims:?}",
                kc.dims,
                vc.dims
            );
        }
        let positions = resolve_positions(positions, b, s_max)?;

        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let q = matmul(&xn, b, h, wq, "wq")?;
        let k_new = matmul(&xn, b, h, wk, "wk")?;
        let v_new = matmul(&xn, b, h, wv, "wv")?;

        // lint: hot-path — write each row's new entry at its own position
        // (the only cache bytes this step touches), then attend in place.
        for bi in 0..b {
            for head in 0..nhs {
                let dst = ((bi * nhs + head) * s_max + positions[bi]) * dh;
                let src = bi * hs + head * dh;
                kc.data[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
                vc.data[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
            }
        }
        // lint: hot-path-end — `merged`/`scores` setup below allocates
        // once per call, outside the per-row loops.

        // Single-token attention over each row's first pos+1 cache entries.
        let mut merged = vec![0f32; b * hs];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores: Vec<f32> = Vec::new();
        // lint: hot-path — the attention loops themselves: reused scratch
        // and in-place cache reads only.
        for bi in 0..b {
            let pos = positions[bi];
            for head in 0..nhs {
                let qrow = bi * hs + head * dh;
                let base = (bi * nhs + head) * s_max;
                scores.clear();
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=pos {
                    let krow = (base + j) * dh;
                    let mut dot = 0f32;
                    for d in 0..dh {
                        dot += q[qrow + d] * kc.data[krow + d];
                    }
                    let sc = dot * scale;
                    if sc > max_s {
                        max_s = sc;
                    }
                    scores.push(sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max_s).exp();
                    denom += *sc;
                }
                for d in 0..dh {
                    let mut acc = 0f32;
                    for (j, p) in scores.iter().enumerate() {
                        acc += p * vc.data[(base + j) * dh + d];
                    }
                    merged[qrow + d] = acc / denom;
                }
            }
        }
        // lint: hot-path-end
        let partial = matmul(&merged, b, hs, wo, "wo")?;
        Ok(Tensor { dims: vec![b, 1, h], data: partial })
    }

    /// Multi-position scoring kernel (the speculative-decoding verify
    /// pass): writes each row's `s` new K/V entries at `positions[bi]
    /// .. positions[bi] + s` and attends query token `i` causally over
    /// `[0, positions[bi] + i]` — one batched pass over a proposed
    /// suffix instead of `s` decode iterations. All writes land before
    /// any query runs, so query `i` sees exactly the cache a sequential
    /// decode would have built (entries of proposal tokens `0..=i` and
    /// nothing later), and every per-row accumulation order matches
    /// [`Self::attn_decode_core`] — results are bit-identical to looping
    /// the single-token kernel.
    #[allow(clippy::too_many_arguments)]
    fn attn_score_core(
        &self,
        st: &StageName,
        x: &Tensor,
        kc: &mut Tensor,
        vc: &mut Tensor,
        positions: DecodePositions<'_>,
        ln: &Tensor,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
    ) -> Result<Tensor> {
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "attn x")?;
        check_bucket(b, st)?;
        if s == 0 {
            bail!("attn score expects at least one proposed token");
        }
        let shard = self.shard_dims(st.tp, h, wq, wk, wv, wo)?;
        let (nhs, dh, hs) = (shard.nhs, shard.dh, shard.hs);
        let s_max = m.max_seq;
        let cache_dims = vec![b, nhs, s_max, dh];
        if kc.dims != cache_dims || vc.dims != cache_dims {
            bail!(
                "score caches have shapes {:?}/{:?}, expected {cache_dims:?}",
                kc.dims,
                vc.dims
            );
        }
        let starts = resolve_positions(positions, b, s_max)?;
        for (bi, &p) in starts.iter().enumerate() {
            if p + s > s_max {
                bail!("scoring {s} tokens at position {p} overruns cache of length {s_max} (row {bi})");
            }
        }

        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let q = matmul(&xn, b * s, h, wq, "wq")?;
        let k_new = matmul(&xn, b * s, h, wk, "wk")?;
        let v_new = matmul(&xn, b * s, h, wv, "wv")?;

        // lint: hot-path — land every row's s new K/V entries in place
        // (the only cache bytes the verify pass touches).
        for bi in 0..b {
            let start = starts[bi];
            for head in 0..nhs {
                for i in 0..s {
                    let dst = ((bi * nhs + head) * s_max + start + i) * dh;
                    let src = (bi * s + i) * hs + head * dh;
                    kc.data[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
                    vc.data[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
                }
            }
        }
        // lint: hot-path-end — `merged`/`scores` allocate once per call,
        // outside the per-row loops.

        let mut merged = vec![0f32; b * s * hs];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores: Vec<f32> = Vec::new();
        // lint: hot-path — the scoring attention loops: reused scratch
        // and in-place cache reads only.
        for bi in 0..b {
            let start = starts[bi];
            for head in 0..nhs {
                let base = (bi * nhs + head) * s_max;
                for i in 0..s {
                    let qrow = (bi * s + i) * hs + head * dh;
                    scores.clear();
                    let mut max_s = f32::NEG_INFINITY;
                    for j in 0..=(start + i) {
                        let krow = (base + j) * dh;
                        let mut dot = 0f32;
                        for d in 0..dh {
                            dot += q[qrow + d] * kc.data[krow + d];
                        }
                        let sc = dot * scale;
                        if sc > max_s {
                            max_s = sc;
                        }
                        scores.push(sc);
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - max_s).exp();
                        denom += *sc;
                    }
                    for d in 0..dh {
                        let mut acc = 0f32;
                        for (j, p) in scores.iter().enumerate() {
                            acc += p * vc.data[(base + j) * dh + d];
                        }
                        merged[qrow + d] = acc / denom;
                    }
                }
            }
        }
        // lint: hot-path-end
        let partial = matmul(&merged, b * s, hs, wo, "wo")?;
        Ok(Tensor { dims: vec![b, s, h], data: partial })
    }

    fn run_mlp(&self, st: &StageName, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        expect_inputs(inputs, 4, "mlp")?;
        let x = self.tensor_arg(&inputs[0], "mlp x")?;
        let ln = self.tensor_arg(&inputs[1], "ln2")?;
        let w1 = self.tensor_arg(&inputs[2], "w1")?;
        let w2 = self.tensor_arg(&inputs[3], "w2")?;
        let m = &self.manifest.model;
        let (b, s, h) = dims3(x, "mlp x")?;
        check_bucket(b, st)?;
        if h != m.hidden {
            bail!("mlp x hidden {h} != model hidden {}", m.hidden);
        }
        let fs = m.ffn / st.tp;
        if w1.dims != vec![h, fs] || w2.dims != vec![fs, h] {
            bail!(
                "mlp shard weights have shapes {:?}/{:?}, expected [{h}, {fs}]/[{fs}, {h}]",
                w1.dims,
                w2.dims
            );
        }
        let rows = b * s;
        let xn = rmsnorm_rows(&x.data, h, &ln.data)?;
        let mut hidden = matmul(&xn, rows, h, w1, "w1")?;
        for v in hidden.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let out = matmul(&hidden, rows, fs, w2, "w2")?;
        Ok(vec![Tensor { dims: vec![b, s, h], data: out }])
    }

    /// Validate shard projection widths against the stage's TP degree.
    fn shard_dims(
        &self,
        tp: usize,
        h: usize,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
    ) -> Result<ShardDims> {
        let m = &self.manifest.model;
        if h != m.hidden {
            bail!("stage input hidden {h} != model hidden {}", m.hidden);
        }
        if tp == 0 || m.heads % tp != 0 {
            bail!("tp={tp} does not divide {} heads", m.heads);
        }
        let nhs = m.heads / tp;
        let dh = m.head_dim;
        let hs = nhs * dh;
        for (name, w) in [("wq", wq), ("wk", wk), ("wv", wv)] {
            if w.dims != vec![h, hs] {
                bail!("{name} shard has shape {:?}, expected [{h}, {hs}]", w.dims);
            }
        }
        if wo.dims != vec![hs, h] {
            bail!("wo shard has shape {:?}, expected [{hs}, {h}]", wo.dims);
        }
        Ok(ShardDims { nhs, dh, hs })
    }
}

struct ShardDims {
    nhs: usize,
    dh: usize,
    hs: usize,
}

/// Resolve a [`DecodePositions`] into validated per-row cache positions.
fn resolve_positions(
    positions: DecodePositions<'_>,
    b: usize,
    s_max: usize,
) -> Result<Vec<usize>> {
    let raw: Vec<i32> = match positions {
        DecodePositions::Scalar(p) => vec![p; b],
        DecodePositions::PerRow(p) => {
            if p.len() != b {
                bail!("decode positions: {} values for batch {b}", p.len());
            }
            p.to_vec()
        }
    };
    raw.into_iter()
        .map(|p| {
            if p < 0 || p as usize >= s_max {
                bail!("decode position {p} outside cache of length {s_max}");
            }
            Ok(p as usize)
        })
        .collect()
}

impl ExecutionBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn weights(&self) -> &Arc<WeightStore> {
        &self.weights
    }

    fn supports_rowwise_decode_positions(&self) -> bool {
        true
    }

    fn sync_view(&self) -> Option<&(dyn ExecutionBackend + Sync)> {
        Some(self)
    }

    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        let st = self.validate_stage(artifact)?;
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        match (st.op, st.prefill) {
            (Op::Embed, _) => self.run_embed(&st, inputs),
            (Op::LmHead, _) => self.run_lm_head(&st, inputs),
            (Op::Attn, true) => self.run_attn_prefill(&st, inputs),
            (Op::Attn, false) => self.run_attn_decode(&st, inputs),
            (Op::Mlp, _) => self.run_mlp(&st, inputs),
        }
    }

    fn execute_attn_decode_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        // lint: hot-path — weight lookups are by-reference; the kernel
        // mutates the caller's caches in place.
        let st = self.validate_stage(artifact)?;
        if st.op != Op::Attn || st.prefill {
            bail!("'{artifact}' is not a decode attention artifact");
        }
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        let ln = self.weights.get(w.ln1)?;
        let wq = self.weights.get(w.wq)?;
        let wk = self.weights.get(w.wk)?;
        let wv = self.weights.get(w.wv)?;
        let wo = self.weights.get(w.wo)?;
        self.attn_decode_core(&st, x, k_cache, v_cache, positions, ln, wq, wk, wv, wo)
        // lint: hot-path-end
    }

    fn execute_attn_score_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        // lint: hot-path — weight lookups are by-reference; the kernel
        // mutates the caller's caches in place.
        let st = self.validate_stage(artifact)?;
        if st.op != Op::Attn || st.prefill {
            bail!("'{artifact}' is not a decode attention artifact");
        }
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        let ln = self.weights.get(w.ln1)?;
        let wq = self.weights.get(w.wq)?;
        let wk = self.weights.get(w.wk)?;
        let wv = self.weights.get(w.wv)?;
        let wo = self.weights.get(w.wo)?;
        self.attn_score_core(&st, x, k_cache, v_cache, positions, ln, wq, wk, wv, wo)
        // lint: hot-path-end
    }

    fn exec_count(&self) -> usize {
        self.exec_count.load(Ordering::Relaxed)
    }
}

/// A [`ReferenceBackend`] pinned to the **seed's functional decode
/// semantics**: caches flow through [`ExecutionBackend::execute`] as
/// values (two full clones plus two full returned copies per shard per
/// layer per token) and TP shards run serially (no
/// [`ExecutionBackend::sync_view`]). Numerically identical to the hot
/// path by construction — parity tests assert it token-for-token, and
/// `benches/decode.rs` measures the hot path against it as the
/// pre-optimization baseline.
pub struct FunctionalBackend(ReferenceBackend);

impl FunctionalBackend {
    pub fn new(inner: ReferenceBackend) -> FunctionalBackend {
        FunctionalBackend(inner)
    }

    /// Load from an artifacts directory (fixture models).
    pub fn load(dir: &Path) -> Result<FunctionalBackend> {
        Ok(FunctionalBackend(ReferenceBackend::load(dir)?))
    }
}

impl ExecutionBackend for FunctionalBackend {
    fn name(&self) -> &'static str {
        "reference-functional"
    }

    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }

    fn weights(&self) -> &Arc<WeightStore> {
        self.0.weights()
    }

    fn supports_rowwise_decode_positions(&self) -> bool {
        true
    }

    // Deliberately NOT overriding `sync_view` (shards stay serial) or
    // `execute_attn_decode_inplace` (decode takes the default
    // clone-and-copy adapter through `execute`).

    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        self.0.execute(artifact, inputs)
    }

    fn exec_count(&self) -> usize {
        self.0.exec_count()
    }
}

// ---- stage-name grammar ----------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Embed,
    LmHead,
    Attn,
    Mlp,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct StageName {
    op: Op,
    prefill: bool,
    tp: usize,
    bucket: usize,
}

impl StageName {
    /// Parse `{op}_{phase}[_tp{T}]_b{B}` artifact names.
    fn parse(name: &str) -> Option<StageName> {
        let (op, rest) = if let Some(r) = name.strip_prefix("embed_") {
            (Op::Embed, r)
        } else if let Some(r) = name.strip_prefix("lm_head_") {
            (Op::LmHead, r)
        } else if let Some(r) = name.strip_prefix("attn_") {
            (Op::Attn, r)
        } else if let Some(r) = name.strip_prefix("mlp_") {
            (Op::Mlp, r)
        } else {
            return None;
        };
        let (prefill, rest) = if let Some(r) = rest.strip_prefix("prefill_") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("decode_") {
            (false, r)
        } else {
            return None;
        };
        let (tp, rest) = match rest.strip_prefix("tp") {
            Some(r) => {
                let (digits, r2) = r.split_once('_')?;
                (digits.parse().ok()?, r2)
            }
            None => (1, rest),
        };
        let bucket = rest.strip_prefix('b')?.parse().ok()?;
        Some(StageName { op, prefill, tp, bucket })
    }
}

// ---- numerics helpers ------------------------------------------------------

/// RMSNorm over rows of width `h` (ref.py `rmsnorm_ref`).
fn rmsnorm_rows(x: &[f32], h: usize, scale: &[f32]) -> Result<Vec<f32>> {
    if scale.len() != h {
        bail!("rmsnorm scale has {} elements, rows have {h}", scale.len());
    }
    if x.len() % h != 0 {
        bail!("rmsnorm input of {} elements is not a multiple of {h}", x.len());
    }
    let mut out = vec![0f32; x.len()];
    for (orow, row) in out.chunks_exact_mut(h).zip(x.chunks_exact(h)) {
        let mut ss = 0f32;
        for &v in row {
            ss += v * v;
        }
        let denom = (ss / h as f32 + RMSNORM_EPS).sqrt();
        for i in 0..h {
            orow[i] = row[i] * scale[i] / denom;
        }
    }
    Ok(out)
}

/// Rows processed together by the blocked matmul kernel (weight-panel
/// loads amortize across the block).
const MM_ROW_BLOCK: usize = 4;
/// Output-column panel width: the per-block accumulator stays resident
/// in registers / L1 instead of streaming the output row every k step.
const MM_COL_PANEL: usize = 32;

/// `[rows, k] @ w[k, n]` row-major matmul.
fn matmul(x: &[f32], rows: usize, k: usize, w: &Tensor, what: &str) -> Result<Vec<f32>> {
    if w.dims.len() != 2 || w.dims[0] != k {
        bail!("{what}: weight shape {:?} incompatible with inner dim {k}", w.dims);
    }
    if x.len() != rows * k {
        bail!("{what}: input of {} elements is not [{rows}, {k}]", x.len());
    }
    let n = w.dims[1];
    let mut out = vec![0f32; rows * n];
    matmul_into(x, rows, k, &w.data, n, &mut out);
    Ok(out)
}

/// Blocked matmul kernel: [`MM_ROW_BLOCK`]×[`MM_COL_PANEL`] register
/// tiles, each weight panel row loaded once per row block instead of
/// once per row. Every output element still accumulates over k in
/// ascending order from 0.0 — bit-identical to the scalar triple loop it
/// replaced (f32 addition order is preserved; nothing is re-associated).
fn matmul_into(x: &[f32], rows: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), rows * n);
    let mut acc = [[0f32; MM_COL_PANEL]; MM_ROW_BLOCK];
    let mut col = 0;
    while col < n {
        let cw = MM_COL_PANEL.min(n - col);
        let mut r0 = 0;
        while r0 < rows {
            let rb = MM_ROW_BLOCK.min(rows - r0);
            for a in acc[..rb].iter_mut() {
                a[..cw].fill(0.0);
            }
            for i in 0..k {
                let wrow = &w[i * n + col..i * n + col + cw];
                for (ri, a) in acc[..rb].iter_mut().enumerate() {
                    let xv = x[(r0 + ri) * k + i];
                    for (av, &wv) in a[..cw].iter_mut().zip(wrow) {
                        *av += xv * wv;
                    }
                }
            }
            for (ri, a) in acc[..rb].iter().enumerate() {
                let dst = (r0 + ri) * n + col;
                out[dst..dst + cw].copy_from_slice(&a[..cw]);
            }
            r0 += rb;
        }
        col += cw;
    }
}

fn dims3(t: &Tensor, what: &str) -> Result<(usize, usize, usize)> {
    if t.dims.len() != 3 {
        bail!("{what}: expected a rank-3 tensor, got {:?}", t.dims);
    }
    Ok((t.dims[0], t.dims[1], t.dims[2]))
}

fn check_bucket(b: usize, st: &StageName) -> Result<()> {
    if b != st.bucket {
        bail!("input batch {b} does not match artifact bucket {}", st.bucket);
    }
    Ok(())
}

fn expect_inputs(inputs: &[InputArg<'_>], n: usize, what: &str) -> Result<()> {
    if inputs.len() != n {
        bail!("{what} expects {n} inputs, got {}", inputs.len());
    }
    Ok(())
}

fn tokens_arg<'t>(a: &'t InputArg<'t>, what: &str) -> Result<(&'t [i32], &'t [usize])> {
    match a {
        InputArg::I32(data, dims) => Ok((*data, dims.as_slice())),
        _ => bail!("{what}: expected int32 tokens"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_parse() {
        assert_eq!(
            StageName::parse("attn_prefill_tp2_b4"),
            Some(StageName { op: Op::Attn, prefill: true, tp: 2, bucket: 4 })
        );
        assert_eq!(
            StageName::parse("embed_decode_b1"),
            Some(StageName { op: Op::Embed, prefill: false, tp: 1, bucket: 1 })
        );
        assert_eq!(
            StageName::parse("lm_head_prefill_b2"),
            Some(StageName { op: Op::LmHead, prefill: true, tp: 1, bucket: 2 })
        );
        assert_eq!(
            StageName::parse("mlp_decode_tp4_b1"),
            Some(StageName { op: Op::Mlp, prefill: false, tp: 4, bucket: 1 })
        );
        assert_eq!(StageName::parse("full_prefill_b1"), None);
        assert_eq!(StageName::parse("attn_warmup_tp2_b1"), None);
        assert_eq!(StageName::parse("attn_prefill_tpx_b1"), None);
    }

    #[test]
    fn rmsnorm_matches_formula() {
        // Constant row of 2.0 with unit scale: 2/sqrt(4 + eps) ≈ 1.
        let out = rmsnorm_rows(&[2.0, 2.0, 2.0, 2.0], 4, &[1.0; 4]).unwrap();
        for v in out {
            assert!((v - 1.0).abs() < 1e-5, "{v}");
        }
        assert!(rmsnorm_rows(&[1.0, 2.0], 3, &[1.0; 3]).is_err());
    }

    #[test]
    fn matmul_small() {
        let w = Tensor { dims: vec![2, 3], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        // [1, 2] @ w = [1+8, 2+10, 3+12]
        let out = matmul(&[1.0, 2.0], 1, 2, &w, "t").unwrap();
        assert_eq!(out, vec![9.0, 12.0, 15.0]);
        assert!(matmul(&[1.0], 1, 2, &w, "t").is_err());
    }

    #[test]
    fn blocked_matmul_matches_scalar_loop_bitwise() {
        // The tiled kernel must be bit-identical to the scalar triple
        // loop across shapes that straddle the block boundaries.
        let mut state = 0xC0FFEEu64;
        for (rows, k, n) in [
            (1, 1, 1),
            (3, 5, 7),
            (4, 16, 32),
            (5, 16, 33),
            (9, 31, 65),
            (2, 8, 100),
        ] {
            let x: Vec<f32> = (0..rows * k)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 1000) as f32 / 500.0 - 1.0)
                .collect();
            let wdata: Vec<f32> = (0..k * n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 1000) as f32 / 500.0 - 1.0)
                .collect();
            let w = Tensor { dims: vec![k, n], data: wdata.clone() };
            let got = matmul(&x, rows, k, &w, "t").unwrap();
            // Scalar reference: the seed's triple loop.
            let mut want = vec![0f32; rows * n];
            for r in 0..rows {
                for i in 0..k {
                    let xv = x[r * k + i];
                    for j in 0..n {
                        want[r * n + j] += xv * wdata[i * n + j];
                    }
                }
            }
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "tiled matmul drifted from the scalar loop at [{rows},{k}]x[{k},{n}]"
            );
        }
    }

    #[test]
    fn softmax_attention_single_position_returns_v() {
        // With one position the softmax weight is exactly 1, so attention
        // output == v regardless of q/k. Exercise via run_attn_prefill on
        // a minimal hand-built model (h=2, heads=1).
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest, Arc::new(WeightStore::default()));
        let x = Tensor { dims: vec![1, 1, 2], data: vec![0.5, -0.25] };
        let ln = Tensor { dims: vec![2], data: vec![1.0, 1.0] };
        let eye = Tensor { dims: vec![2, 2], data: vec![1.0, 0.0, 0.0, 1.0] };
        let outs = be
            .execute(
                "attn_prefill_tp1_b1",
                &[
                    InputArg::F32(&x),
                    InputArg::F32(&ln),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                    InputArg::F32(&eye),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 3);
        // partial == v == rmsnorm(x) when every projection is identity.
        let xn = rmsnorm_rows(&x.data, 2, &ln.data).unwrap();
        for (a, b) in outs[0].data.iter().zip(&xn) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // caches zero-padded to max_seq.
        assert_eq!(outs[1].dims, vec![1, 1, 2, 2]);
        assert_eq!(&outs[1].data[0..2], &xn[..]);
        assert_eq!(&outs[1].data[2..4], &[0.0, 0.0]);
        assert_eq!(be.exec_count(), 1);
    }

    #[test]
    fn inplace_decode_matches_functional_execute() {
        // The in-place entry point and the value-passing execute()
        // contract must produce bit-identical partials and caches.
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":4,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[2],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let mut ws = WeightStore::default();
        let eye = Tensor { dims: vec![2, 2], data: vec![1.0, 0.0, 0.0, 1.0] };
        let ln = Tensor { dims: vec![2], data: vec![1.0, 1.0] };
        ws.insert("layers.0.ln1", ln);
        for name in ["layers.0.wq", "layers.0.wk", "layers.0.wv", "layers.0.wo"] {
            ws.insert(name, eye.clone());
        }
        let be = ReferenceBackend::with_weights(manifest, Arc::new(ws));
        let x = Tensor { dims: vec![2, 1, 2], data: vec![0.5, -0.25, 1.5, 0.75] };
        let mut kc = Tensor { dims: vec![2, 1, 4, 2], data: (0..16).map(|i| i as f32 * 0.1).collect() };
        let mut vc = Tensor { dims: vec![2, 1, 4, 2], data: (0..16).map(|i| i as f32 * -0.1).collect() };

        let functional = be
            .execute(
                "attn_decode_tp1_b2",
                &[
                    InputArg::F32(&x),
                    InputArg::F32(&kc),
                    InputArg::F32(&vc),
                    InputArg::I32(&[2, 1], vec![2]),
                    InputArg::Weight("layers.0.ln1"),
                    InputArg::Weight("layers.0.wq"),
                    InputArg::Weight("layers.0.wk"),
                    InputArg::Weight("layers.0.wv"),
                    InputArg::Weight("layers.0.wo"),
                ],
            )
            .unwrap();

        let w = AttnShardWeights {
            ln1: "layers.0.ln1",
            wq: "layers.0.wq",
            wk: "layers.0.wk",
            wv: "layers.0.wv",
            wo: "layers.0.wo",
        };
        let partial = be
            .execute_attn_decode_inplace(
                "attn_decode_tp1_b2",
                &x,
                &mut kc,
                &mut vc,
                DecodePositions::PerRow(&[2, 1]),
                &w,
            )
            .unwrap();
        assert_eq!(partial, functional[0], "partials diverged");
        assert_eq!(kc, functional[1], "k caches diverged");
        assert_eq!(vc, functional[2], "v caches diverged");
        // Outside each row's written position, the caches are untouched.
        assert_eq!(kc.data[0..4], (0..4).map(|i| i as f32 * 0.1).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn batched_score_matches_sequential_decode_bitwise() {
        // The multi-position verify kernel must reproduce exactly what
        // looping the single-token decode kernel produces: same partials,
        // same cache bytes. (The trait's default adapter IS that loop, so
        // this also pins override == adapter.)
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":4,"heads":2,"vocab":4,
                        "prompt_len":1,"max_seq":8,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[2],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let mut ws = WeightStore::default();
        let mut state = 0x5C02Eu64;
        let mut rnd = |n: usize| -> Vec<f32> {
            (0..n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 1000) as f32 / 500.0 - 1.0)
                .collect()
        };
        ws.insert("layers.0.ln1", Tensor { dims: vec![4], data: rnd(4) });
        for name in ["layers.0.wq", "layers.0.wk", "layers.0.wv", "layers.0.wo"] {
            ws.insert(name, Tensor { dims: vec![4, 4], data: rnd(16) });
        }
        let be = ReferenceBackend::with_weights(manifest, Arc::new(ws));
        let w = AttnShardWeights {
            ln1: "layers.0.ln1",
            wq: "layers.0.wq",
            wk: "layers.0.wk",
            wv: "layers.0.wv",
            wo: "layers.0.wo",
        };
        // 3 proposed tokens per row, rows at different cache depths.
        let (b, s, h) = (2usize, 3usize, 4usize);
        let x = Tensor { dims: vec![b, s, h], data: rnd(b * s * h) };
        let cache_init = rnd(2 * 2 * 8 * 2);
        let starts = [3i32, 1i32];

        let mut kc_seq = Tensor { dims: vec![2, 2, 8, 2], data: cache_init.clone() };
        let mut vc_seq = Tensor { dims: vec![2, 2, 8, 2], data: cache_init.clone() };
        let mut seq_partial = vec![0f32; b * s * h];
        for i in 0..s {
            let mut xi = Tensor { dims: vec![b, 1, h], data: vec![0.0; b * h] };
            for bi in 0..b {
                let src = (bi * s + i) * h;
                xi.data[bi * h..(bi + 1) * h].copy_from_slice(&x.data[src..src + h]);
            }
            let pos: Vec<i32> = starts.iter().map(|&p| p + i as i32).collect();
            let p = be
                .execute_attn_decode_inplace(
                    "attn_decode_tp1_b2",
                    &xi,
                    &mut kc_seq,
                    &mut vc_seq,
                    DecodePositions::PerRow(&pos),
                    &w,
                )
                .unwrap();
            for bi in 0..b {
                let dst = (bi * s + i) * h;
                seq_partial[dst..dst + h].copy_from_slice(&p.data[bi * h..(bi + 1) * h]);
            }
        }

        let mut kc = Tensor { dims: vec![2, 2, 8, 2], data: cache_init.clone() };
        let mut vc = Tensor { dims: vec![2, 2, 8, 2], data: cache_init };
        let batched = be
            .execute_attn_score_inplace(
                "attn_decode_tp1_b2",
                &x,
                &mut kc,
                &mut vc,
                DecodePositions::PerRow(&starts),
                &w,
            )
            .unwrap();
        assert_eq!(batched.dims, vec![b, s, h]);
        assert!(
            batched.data.iter().zip(&seq_partial).all(|(a, c)| a.to_bits() == c.to_bits()),
            "batched score partials diverged from the sequential decode loop"
        );
        assert_eq!(kc, kc_seq, "k caches diverged");
        assert_eq!(vc, vc_seq, "v caches diverged");
        // Overrunning the cache is rejected up front.
        assert!(be
            .execute_attn_score_inplace(
                "attn_decode_tp1_b2",
                &x,
                &mut kc,
                &mut vc,
                DecodePositions::Scalar(6),
                &w,
            )
            .is_err());
    }

    #[test]
    fn inplace_decode_rejects_non_decode_artifacts() {
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest, Arc::new(WeightStore::default()));
        let x = Tensor { dims: vec![1, 1, 2], data: vec![0.0; 2] };
        let mut kc = Tensor { dims: vec![1, 1, 2, 2], data: vec![0.0; 4] };
        let mut vc = kc.clone();
        let w = AttnShardWeights { ln1: "a", wq: "b", wk: "c", wv: "d", wo: "e" };
        let err = be.execute_attn_decode_inplace(
            "attn_prefill_tp1_b1",
            &x,
            &mut kc,
            &mut vc,
            DecodePositions::Scalar(0),
            &w,
        );
        assert!(err.is_err());
    }

    #[test]
    fn unknown_artifacts_rejected() {
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest, Arc::new(WeightStore::default()));
        assert!(be.execute("full_prefill_b1", &[]).is_err());
        assert!(be.execute("attn_prefill_tp2_b1", &[]).is_err()); // tp 2 absent
        assert!(be.execute("embed_prefill_b4", &[]).is_err()); // bucket 4 absent
    }

    #[test]
    fn backend_is_sync_and_exposes_sync_view() {
        fn assert_sync<T: Sync>() {}
        assert_sync::<ReferenceBackend>();
        let manifest = Manifest::parse(
            r#"{
              "model": {"name":"t","layers":1,"hidden":2,"heads":1,"vocab":4,
                        "prompt_len":1,"max_seq":2,"head_dim":2,"ffn":8},
              "tp_degrees":[1],
              "batch_buckets":[1],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap();
        let be = ReferenceBackend::with_weights(manifest.clone(), Arc::new(WeightStore::default()));
        assert!(be.sync_view().is_some());
        // The functional baseline deliberately stays serial.
        let fb = FunctionalBackend::new(ReferenceBackend::with_weights(
            manifest,
            Arc::new(WeightStore::default()),
        ));
        assert!(fb.sync_view().is_none());
        assert_eq!(fb.name(), "reference-functional");
        assert!(fb.supports_rowwise_decode_positions());
    }
}
