//! Byte-level tokenizer for the demo model (vocab = 256).
//!
//! Prompts are normalized to exactly `prompt_len` tokens: UTF-8 bytes,
//! truncated from the left (keep the most recent context) and left-padded
//! with `PAD` — the serving shape contract of the AOT artifacts, which
//! keeps KV caches contiguous without per-request length plumbing.

pub const PAD: i32 = 0;

/// Encode text to exactly `prompt_len` byte tokens.
pub fn encode(text: &str, prompt_len: usize) -> Vec<i32> {
    encode_report(text, prompt_len).0
}

/// [`encode`], also reporting the prompt's full pre-truncation token
/// count so callers can surface truncation instead of dropping the
/// oldest tokens silently: `full > prompt_len` means the prompt was
/// left-truncated to its most recent `prompt_len` tokens.
pub fn encode_report(text: &str, prompt_len: usize) -> (Vec<i32>, usize) {
    let bytes = text.as_bytes();
    let take = bytes.len().min(prompt_len);
    let mut out = vec![PAD; prompt_len - take];
    out.extend(bytes[bytes.len() - take..].iter().map(|&b| b as i32));
    (out, bytes.len())
}

/// Decode generated tokens back to text (lossy; PAD dropped).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t != PAD && (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_pads_left() {
        let t = encode("hi", 5);
        assert_eq!(t, vec![0, 0, 0, b'h' as i32, b'i' as i32]);
    }

    #[test]
    fn encode_truncates_left() {
        let t = encode("abcdef", 3);
        assert_eq!(t, vec![b'd' as i32, b'e' as i32, b'f' as i32]);
    }

    #[test]
    fn roundtrip_ascii() {
        let t = encode("hello", 8);
        assert_eq!(decode(&t), "hello");
    }

    #[test]
    fn decode_skips_pad_and_out_of_range() {
        assert_eq!(decode(&[0, 72, 105, 300, -5]), "Hi");
    }

    #[test]
    fn encode_report_surfaces_truncation() {
        let (tokens, full) = encode_report("abcdef", 3);
        assert_eq!(tokens, vec![b'd' as i32, b'e' as i32, b'f' as i32]);
        assert_eq!(full, 6, "full pre-truncation length");
        let (tokens, full) = encode_report("hi", 5);
        assert_eq!(tokens.len(), 5);
        assert_eq!(full, 2, "short prompts report their own length");
    }

    #[test]
    fn exact_length() {
        for len in [1, 16, 32] {
            assert_eq!(encode("some text", len).len(), len);
        }
    }
}
