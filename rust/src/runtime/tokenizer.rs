//! Byte-level tokenizer for the demo model (vocab = 256).
//!
//! Prompts are normalized to exactly `prompt_len` tokens: UTF-8 bytes,
//! truncated from the left (keep the most recent context) and left-padded
//! with `PAD` — the serving shape contract of the AOT artifacts, which
//! keeps KV caches contiguous without per-request length plumbing.

pub const PAD: i32 = 0;

/// Encode text to exactly `prompt_len` byte tokens.
pub fn encode(text: &str, prompt_len: usize) -> Vec<i32> {
    encode_report(text, prompt_len).0
}

/// [`encode`], also reporting the prompt's full pre-truncation token
/// count so callers can surface truncation instead of dropping the
/// oldest tokens silently: `full > prompt_len` means the prompt was
/// left-truncated to its most recent `prompt_len` tokens.
pub fn encode_report(text: &str, prompt_len: usize) -> (Vec<i32>, usize) {
    let bytes = text.as_bytes();
    let take = bytes.len().min(prompt_len);
    let mut out = vec![PAD; prompt_len - take];
    out.extend(bytes[bytes.len() - take..].iter().map(|&b| b as i32));
    (out, bytes.len())
}

/// Decode generated tokens back to text (lossy; PAD dropped).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t != PAD && (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

const REPLACEMENT: char = '\u{FFFD}';

/// Incremental UTF-8 decoder for per-token text deltas.
///
/// The vocab is byte-level, so a multi-byte UTF-8 character arrives one
/// token at a time; decoding each token alone renders every such
/// character as replacement glyphs mid-stream. `Utf8Stream` buffers an
/// incomplete (but still valid) sequence — at most 3 bytes — and emits
/// its text the moment it completes or becomes invalid, with exactly the
/// "U+FFFD substitution of maximal subparts" semantics of
/// [`String::from_utf8_lossy`]: the concatenation of every
/// [`Self::push`] delta plus the final [`Self::finish`] equals
/// [`decode`] over the same tokens.
#[derive(Debug, Clone, Default)]
pub struct Utf8Stream {
    buf: [u8; 4],
    len: usize,
}

impl Utf8Stream {
    pub fn new() -> Utf8Stream {
        Utf8Stream::default()
    }

    /// Feed one generated token; returns the text now safe to emit
    /// (empty while a multi-byte sequence is incomplete). PAD and
    /// out-of-range tokens are dropped, mirroring [`decode`].
    pub fn push(&mut self, token: i32) -> String {
        if token == PAD || !(0..256).contains(&token) {
            return String::new();
        }
        self.buf[self.len] = token as u8;
        self.len += 1;
        let mut out = String::new();
        while self.len > 0 {
            let lead = self.buf[0];
            if lead < 0x80 {
                out.push(lead as char);
                self.pop_front(1);
                continue;
            }
            let (want, lo, hi) = lead_info(lead);
            if want == 0 {
                // Continuation byte or invalid lead in lead position.
                out.push(REPLACEMENT);
                self.pop_front(1);
                continue;
            }
            // Scan the continuation bytes present so far; an invalid one
            // ends the maximal subpart `buf[..i]` as one replacement and
            // reprocesses the offender as a fresh lead.
            let mut bad_at = None;
            for (i, &b) in self.buf[..self.len].iter().enumerate().skip(1) {
                let (lo_i, hi_i) = if i == 1 { (lo, hi) } else { (0x80, 0xBF) };
                if b < lo_i || b > hi_i {
                    bad_at = Some(i);
                    break;
                }
            }
            if let Some(i) = bad_at {
                out.push(REPLACEMENT);
                self.pop_front(i);
                continue;
            }
            if self.len < want {
                break; // valid prefix: wait for the rest of the character
            }
            match std::str::from_utf8(&self.buf[..want]) {
                Ok(s) => out.push_str(s),
                // Unreachable: the ranges above admit exactly valid UTF-8.
                Err(_) => out.push(REPLACEMENT),
            }
            self.pop_front(want);
        }
        out
    }

    /// Bytes buffered awaiting the rest of a multi-byte character.
    pub fn pending(&self) -> usize {
        self.len
    }

    /// Flush at end of stream: a trailing incomplete sequence is one
    /// maximal subpart, i.e. a single replacement character.
    pub fn finish(&mut self) -> String {
        if self.len == 0 {
            String::new()
        } else {
            self.len = 0;
            REPLACEMENT.to_string()
        }
    }

    fn pop_front(&mut self, n: usize) {
        self.buf.copy_within(n..self.len, 0);
        self.len -= n;
    }
}

/// `(sequence length, valid second-byte range)` for a UTF-8 lead byte;
/// length 0 marks an invalid lead. The second-byte ranges are the WHATWG
/// table (overlongs and surrogates excluded), which is what makes the
/// maximal-subpart accounting agree with [`String::from_utf8_lossy`].
fn lead_info(b: u8) -> (usize, u8, u8) {
    match b {
        0xC2..=0xDF => (2, 0x80, 0xBF),
        0xE0 => (3, 0xA0, 0xBF),
        0xE1..=0xEC => (3, 0x80, 0xBF),
        0xED => (3, 0x80, 0x9F),
        0xEE..=0xEF => (3, 0x80, 0xBF),
        0xF0 => (4, 0x90, 0xBF),
        0xF1..=0xF3 => (4, 0x80, 0xBF),
        0xF4 => (4, 0x80, 0x8F),
        _ => (0, 0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_pads_left() {
        let t = encode("hi", 5);
        assert_eq!(t, vec![0, 0, 0, b'h' as i32, b'i' as i32]);
    }

    #[test]
    fn encode_truncates_left() {
        let t = encode("abcdef", 3);
        assert_eq!(t, vec![b'd' as i32, b'e' as i32, b'f' as i32]);
    }

    #[test]
    fn roundtrip_ascii() {
        let t = encode("hello", 8);
        assert_eq!(decode(&t), "hello");
    }

    #[test]
    fn decode_skips_pad_and_out_of_range() {
        assert_eq!(decode(&[0, 72, 105, 300, -5]), "Hi");
    }

    #[test]
    fn encode_report_surfaces_truncation() {
        let (tokens, full) = encode_report("abcdef", 3);
        assert_eq!(tokens, vec![b'd' as i32, b'e' as i32, b'f' as i32]);
        assert_eq!(full, 6, "full pre-truncation length");
        let (tokens, full) = encode_report("hi", 5);
        assert_eq!(tokens.len(), 5);
        assert_eq!(full, 2, "short prompts report their own length");
    }

    #[test]
    fn exact_length() {
        for len in [1, 16, 32] {
            assert_eq!(encode("some text", len).len(), len);
        }
    }

    fn stream_all(tokens: &[i32]) -> String {
        let mut s = Utf8Stream::new();
        let mut out: String = tokens.iter().map(|&t| s.push(t)).collect();
        out.push_str(&s.finish());
        out
    }

    #[test]
    fn utf8_stream_buffers_split_characters() {
        // "月" = E6 9C 88: nothing emits until the sequence completes.
        let mut s = Utf8Stream::new();
        assert_eq!(s.push(0xE6), "");
        assert_eq!(s.pending(), 1);
        assert_eq!(s.push(0x9C), "");
        assert_eq!(s.push(0x88), "月");
        assert_eq!(s.pending(), 0);
        // 4-byte emoji split across pushes, with ASCII on either side.
        let mut s = Utf8Stream::new();
        let mut out = String::new();
        for &b in b"a\xF0\x9F\xA6\x80b" {
            out.push_str(&s.push(b as i32));
        }
        assert_eq!(out, "a🦀b");
    }

    #[test]
    fn utf8_stream_replacement_semantics_match_lossy_decode() {
        // Directed cases: invalid continuation ends a maximal subpart as
        // ONE replacement; truncated tails flush to one replacement.
        for bytes in [
            &b"\xE2\x28"[..],       // 3-byte lead + invalid continuation
            b"\xF0\x9F\x28",        // 2-byte maximal subpart, then '('
            b"\xE6",                // truncated tail
            b"\xE6\x9C",            // longer truncated tail
            b"\xC0\xAF",            // overlong encoding is invalid per byte
            b"\xED\xA0\x80",        // surrogate
            b"\xF4\x90\x80\x80",    // above U+10FFFF
            b"\x80",                // bare continuation
            b"a\xC2b",              // aborted 2-byte sequence
        ] {
            let tokens: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
            assert_eq!(
                stream_all(&tokens),
                String::from_utf8_lossy(bytes),
                "stream drifted from from_utf8_lossy on {bytes:?}"
            );
        }
    }

    #[test]
    fn utf8_stream_drops_pad_like_decode() {
        // PAD (and out-of-range tokens) vanish even mid-sequence,
        // mirroring decode()'s filter-then-decode order.
        let tokens = [0xE6, PAD, 0x9C, 999, 0x88, -3];
        assert_eq!(stream_all(&tokens), "月");
        assert_eq!(stream_all(&tokens), decode(&tokens));
    }

    #[test]
    fn utf8_stream_fuzz_matches_decode() {
        // Random byte soup (PAD included): the concatenated deltas plus
        // the flush must equal decode() exactly.
        let mut state = 0x5EEDu64;
        for _ in 0..2000 {
            let n = (crate::util::rng::splitmix64(&mut state) % 12) as usize;
            let tokens: Vec<i32> = (0..n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 256) as i32)
                .collect();
            assert_eq!(
                stream_all(&tokens),
                decode(&tokens),
                "stream drifted from decode() on {tokens:?}"
            );
        }
    }

    #[test]
    fn utf8_stream_finish_is_idempotent() {
        let mut s = Utf8Stream::new();
        s.push(0xE6);
        assert_eq!(s.finish(), "\u{FFFD}");
        assert_eq!(s.finish(), "");
    }
}
