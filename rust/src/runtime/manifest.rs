//! `manifest.json` parsing: the artifact catalog emitted by the AOT step.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parameter or output descriptor of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled stage variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<String>,
}

/// Demo-model architecture as recorded by the AOT step.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub vocab: usize,
    pub prompt_len: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub ffn: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub tp_degrees: Vec<usize>,
    pub batch_buckets: Vec<usize>,
    pub weight_order: Vec<String>,
    pub artifacts: std::collections::BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let m = j.get("model")?;
        let model = ModelInfo {
            name: m.str("name")?.to_string(),
            layers: m.usize("layers")?,
            hidden: m.usize("hidden")?,
            heads: m.usize("heads")?,
            vocab: m.usize("vocab")?,
            prompt_len: m.usize("prompt_len")?,
            max_seq: m.usize("max_seq")?,
            head_dim: m.usize("head_dim")?,
            ffn: m.usize("ffn")?,
        };
        if model.hidden != model.heads * model.head_dim {
            bail!("inconsistent manifest: hidden != heads*head_dim");
        }
        let tp_degrees = usize_list(j.arr("tp_degrees")?)?;
        let batch_buckets = usize_list(j.arr("batch_buckets")?)?;
        let weight_order: Vec<String> = j
            .arr("weight_order")?
            .iter()
            .map(|x| x.as_str().map(str::to_string))
            .collect::<Result<_, _>>()?;
        let mut artifacts = std::collections::BTreeMap::new();
        for (name, spec) in j.get("artifacts")?.as_obj()? {
            let params = spec
                .arr("params")?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>>>()?;
            let outputs: Vec<String> = spec
                .arr("outputs")?
                .iter()
                .map(|x| x.as_str().map(str::to_string))
                .collect::<Result<_, _>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: spec.str("file")?.to_string(),
                    params,
                    outputs,
                },
            );
        }
        Ok(Manifest { model, tp_degrees, batch_buckets, weight_order, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))
    }

    /// Pick the smallest batch bucket that fits `batch`.
    pub fn bucket_for(&self, batch: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .filter(|&b| b >= batch)
            .min()
            .with_context(|| {
                format!("batch {batch} exceeds largest bucket {:?}", self.batch_buckets)
            })
    }
}

fn parse_param(j: &Json) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: j.str("name")?.to_string(),
        shape: usize_list(j.arr("shape")?)?,
        dtype: j.str("dtype")?.to_string(),
    })
}

fn usize_list(arr: &[Json]) -> Result<Vec<usize>> {
    arr.iter().map(|x| x.as_usize().map_err(Into::into)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"name":"demo","layers":6,"hidden":128,"heads":4,"vocab":256,
                "prompt_len":32,"max_seq":64,"head_dim":32,"ffn":512},
      "tp_degrees":[1,2,4],
      "batch_buckets":[1,4],
      "weight_order":["embed","final_ln"],
      "artifacts":{
        "mlp_prefill_tp2_b1":{
          "file":"mlp_prefill_tp2_b1.hlo.txt",
          "params":[{"name":"x","shape":[1,32,128],"dtype":"float32"},
                     {"name":"ln2","shape":[128],"dtype":"float32"}],
          "outputs":["partial"]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.hidden, 128);
        assert_eq!(m.tp_degrees, vec![1, 2, 4]);
        let a = m.artifact("mlp_prefill_tp2_b1").unwrap();
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.params[0].shape, vec![1, 32, 128]);
        assert_eq!(a.params[0].elements(), 1 * 32 * 128);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 1);
        assert_eq!(m.bucket_for(2).unwrap(), 4);
        assert_eq!(m.bucket_for(4).unwrap(), 4);
        assert!(m.bucket_for(5).is_err());
    }

    #[test]
    fn rejects_inconsistent_model() {
        let bad = SAMPLE.replace("\"head_dim\":32", "\"head_dim\":16");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.model.layers, 6);
        assert_eq!(m.artifacts.len(), 36);
        for (_, a) in &m.artifacts {
            assert!(!a.params.is_empty());
            assert!(!a.outputs.is_empty());
        }
        // key artifacts present
        for name in [
            "embed_prefill_b1",
            "attn_prefill_tp2_b4",
            "attn_decode_tp4_b1",
            "mlp_decode_tp1_b4",
            "lm_head_decode_b1",
            "full_prefill_b1",
        ] {
            assert!(m.artifacts.contains_key(name), "{name}");
        }
    }
}
