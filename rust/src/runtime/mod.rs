//! Serving runtime: PJRT client wrapper, AOT artifact/weights loading,
//! and the byte tokenizer. Python never runs here — everything executes
//! from `artifacts/*.hlo.txt` produced once by `make artifacts`.

pub mod engine;
pub mod manifest;
pub mod tokenizer;
pub mod weights;

pub use engine::{
    literal_to_tensor_f32, literal_to_vec_i32, tensor_to_literal, InputArg, ModelRuntime,
};
pub use manifest::{ArtifactSpec, Manifest, ModelInfo, ParamSpec};
pub use weights::{Tensor, WeightStore};
