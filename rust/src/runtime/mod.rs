//! Serving runtime: the [`ExecutionBackend`] seam, AOT artifact/weights
//! loading, the byte tokenizer, and the backend implementations — the
//! pure-Rust [`ReferenceBackend`] (always available) and the PJRT-backed
//! [`ModelRuntime`] behind the `pjrt` cargo feature. Python never runs
//! here — everything executes from the artifacts directory produced once
//! by `make artifacts` (or, for the reference backend, from
//! `manifest.json` + `weights.bin` alone).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod faults;
pub mod kvcache;
pub mod manifest;
pub mod reference;
pub mod tokenizer;
pub mod weights;

pub use backend::{
    load_backend, make_backend, AttnShardWeights, BackendKind, DecodePositions, ExecutionBackend,
    InputArg,
};
#[cfg(feature = "pjrt")]
pub use engine::{literal_to_tensor_f32, literal_to_vec_i32, tensor_to_literal, ModelRuntime};
pub use faults::{
    make_fault_backend, FaultInjectingBackend, FaultKind, FaultOp, FaultPlan, FaultSpec,
};
pub use kvcache::{AppendOp, BlockPool, BlockTable, KvPolicy, PrefixCache};
pub use manifest::{ArtifactSpec, Manifest, ModelInfo, ParamSpec};
pub use reference::{FunctionalBackend, ReferenceBackend};
pub use tokenizer::Utf8Stream;
pub use weights::{Tensor, WeightStore};
