//! PJRT execution backend (`pjrt` cargo feature): loads HLO-text
//! artifacts, compiles them on the CPU PJRT client, and executes them
//! with host tensors.
//!
//! PJRT handles in the `xla` crate are `Rc`-based (not `Send`), so a
//! [`ModelRuntime`] is **thread-confined**: each pipeline worker thread
//! constructs its own (sharing the parsed [`WeightStore`] via `Arc`).
//! Executables are compiled lazily and cached per runtime.
//!
//! The default workspace wires the `xla` dependency to the in-tree API
//! stub (`vendor/xla-stub`), which type-checks this path but fails at
//! client construction; swap it for the real `xla` crate to serve on an
//! actual PJRT runtime.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::backend::{ExecutionBackend, InputArg};
use super::manifest::Manifest;
use super::weights::{Tensor, WeightStore};

/// Thread-confined PJRT execution context for the demo model.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub weights: Arc<WeightStore>,
    client: xla::PjRtClient,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Weight tensors converted to literals once and reused across calls
    /// (§Perf: saves one host copy per weight per execution).
    weight_literals: RefCell<HashMap<String, Rc<xla::Literal>>>,
    dir: PathBuf,
    /// Cumulative PJRT executions (hot-path metric).
    pub exec_count: RefCell<usize>,
}

impl ModelRuntime {
    /// Load manifest + weights from an artifacts directory and create a
    /// CPU PJRT client.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&dir.join("weights.bin"))?);
        Self::with_weights(dir, manifest, weights)
    }

    /// Create a runtime re-using an already-parsed weight store (what the
    /// per-thread workers do).
    pub fn with_weights(
        dir: &Path,
        manifest: Manifest,
        weights: Arc<WeightStore>,
    ) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ModelRuntime {
            manifest,
            weights,
            client,
            executables: RefCell::new(HashMap::new()),
            weight_literals: RefCell::new(HashMap::new()),
            dir: dir.to_path_buf(),
            exec_count: RefCell::new(0),
        })
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.executables.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }

    /// Execute an artifact on literal inputs; unpacks the output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.params.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.params.len(),
                inputs.len()
            );
        }
        let exe = self.executable(name)?;
        *self.exec_count.borrow_mut() += 1;
        let bufs = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{name}'"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{name}'"))?;
        // AOT lowers with return_tuple=True: always a tuple.
        Ok(lit.to_tuple()?)
    }

    /// Execute with host tensors; `InputArg::Weight` inputs resolve
    /// through the per-runtime literal cache.
    pub fn execute_t(&self, name: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        let args: Vec<ArgLit> = inputs
            .iter()
            .map(|a| match a {
                InputArg::Weight(w) => Ok(ArgLit::Cached(self.weight_literal(w)?)),
                other => Ok(ArgLit::Own(arg_to_literal(other)?)),
            })
            .collect::<Result<_>>()?;
        let spec = self.manifest.artifact(name)?;
        if args.len() != spec.params.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.params.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        *self.exec_count.borrow_mut() += 1;
        let bufs = exe
            .execute::<ArgLit>(&args)
            .with_context(|| format!("executing '{name}'"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{name}'"))?;
        let outs = lit.to_tuple()?;
        let mut tensors = Vec::with_capacity(outs.len());
        for o in outs.iter() {
            tensors.push(literal_to_tensor_f32(o, None)?);
        }
        Ok(tensors)
    }

    /// Weight tensor as a cached literal (uploaded at most once).
    pub fn weight_literal(&self, name: &str) -> Result<Rc<xla::Literal>> {
        if let Some(l) = self.weight_literals.borrow().get(name) {
            return Ok(l.clone());
        }
        let lit = Rc::new(tensor_to_literal(self.weights.get(name)?)?);
        self.weight_literals
            .borrow_mut()
            .insert(name.to_string(), lit.clone());
        Ok(lit)
    }
}

impl ExecutionBackend for ModelRuntime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn weights(&self) -> &Arc<WeightStore> {
        &self.weights
    }

    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        self.execute_t(artifact, inputs)
    }

    fn exec_count(&self) -> usize {
        *self.exec_count.borrow()
    }
}

/// Owned-or-cached literal argument (borrowable as `&Literal` for
/// `PjRtLoadedExecutable::execute`).
enum ArgLit {
    Own(xla::Literal),
    Cached(Rc<xla::Literal>),
}

impl std::borrow::Borrow<xla::Literal> for ArgLit {
    fn borrow(&self) -> &xla::Literal {
        match self {
            ArgLit::Own(l) => l,
            ArgLit::Cached(r) => r,
        }
    }
}

/// Host input → literal (weights are resolved by `execute_t` instead).
fn arg_to_literal(arg: &InputArg<'_>) -> Result<xla::Literal> {
    match arg {
        InputArg::F32(t) => tensor_to_literal(t),
        InputArg::I32(data, dims) => {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
        }
        InputArg::ScalarI32(x) => Ok(xla::Literal::scalar(*x)),
        InputArg::Weight(name) => {
            bail!("weight argument '{name}' reached literal lowering; execute_t resolves weights")
        }
    }
}

/// Host tensor → literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&t.data).reshape(&t.dims_i64())?)
}

/// Literal → host f32 tensor; dims read from the literal when `None`.
pub fn literal_to_tensor_f32(lit: &xla::Literal, dims: Option<Vec<usize>>) -> Result<Tensor> {
    let data: Vec<f32> = lit.to_vec::<f32>()?;
    let dims = match dims {
        Some(d) => d,
        None => match lit.shape()? {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            other => bail!("expected array literal, got {other:?}"),
        },
    };
    let n: usize = dims.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("dims {dims:?} disagree with {} elements", data.len());
    }
    Ok(Tensor { dims, data })
}

/// Literal → host i32 vector (argmax outputs, tokens).
pub fn literal_to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
