//! The execution-backend seam: the trait the pipeline coordinator and
//! service layer program against, decoupling scheduling/serving from the
//! execution substrate (the multi-backend direction HexGen-2 and Helix
//! both take).
//!
//! A backend executes named stage artifacts (`attn_prefill_tp2_b4`, …)
//! on host tensors. Two implementations ship in-tree:
//!
//! * [`ReferenceBackend`](super::reference::ReferenceBackend) — pure
//!   Rust, mirrors the numerics of `python/compile/kernels/ref.py`; zero
//!   native dependencies, always available (the default build).
//! * [`ModelRuntime`](super::engine::ModelRuntime) — PJRT-backed, behind
//!   the `pjrt` cargo feature; executes the AOT-lowered HLO artifacts.
//!
//! Backends need not be `Send`: each pipeline worker thread constructs
//! its own instance from a shared [`BackendKind`] + parsed
//! [`WeightStore`] (PJRT handles are `Rc`-based and thread-confined).
//! Backends that *are* shareable across threads advertise it through
//! [`ExecutionBackend::sync_view`], which the pipeline uses to fan TP
//! shard executions out over scoped threads.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::manifest::Manifest;
use super::weights::{Tensor, WeightStore};

/// An input argument to [`ExecutionBackend::execute`].
pub enum InputArg<'a> {
    /// f32 tensor (activations, KV caches).
    F32(&'a Tensor),
    /// int32 tensor (tokens) with its dimensions.
    I32(&'a [i32], Vec<usize>),
    /// int32 scalar (decode position).
    ScalarI32(i32),
    /// Named weight, resolved through the backend's weight store (and
    /// any backend-side upload cache).
    Weight(&'a str),
}

/// Decode positions for [`ExecutionBackend::execute_attn_decode_inplace`]:
/// a batch-wide scalar (uniform batches, the shape the AOT artifacts
/// compile) or a per-row vector (continuous batching co-batches rows at
/// different cache depths).
#[derive(Debug, Clone, Copy)]
pub enum DecodePositions<'a> {
    Scalar(i32),
    PerRow(&'a [i32]),
}

/// Weight names of one attention shard, resolved through the backend's
/// weight store by the decode hot-path entry point. Precomputed per
/// (stage, layer, rank) by the pipeline so the per-token loop allocates
/// no name strings.
#[derive(Debug, Clone, Copy)]
pub struct AttnShardWeights<'a> {
    pub ln1: &'a str,
    pub wq: &'a str,
    pub wk: &'a str,
    pub wv: &'a str,
    pub wo: &'a str,
}

/// Stage-execution substrate: load artifacts once, then run prefill and
/// decode stage functions on host tensors.
pub trait ExecutionBackend {
    /// Short backend identifier (`"reference"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// The artifact catalog + model architecture this backend serves.
    fn manifest(&self) -> &Manifest;

    /// The parsed weight store (shared across worker threads).
    fn weights(&self) -> &Arc<WeightStore>;

    /// Execute the named stage artifact; returns its outputs in the
    /// manifest's declared order.
    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>>;

    /// Whether `attn_decode` accepts a per-row `[b]` position vector in
    /// place of the batch-wide scalar. Continuous batching needs this to
    /// co-batch rows at different cache depths; backends bound to
    /// AOT-compiled artifact signatures (scalar `pos`) return `false`
    /// and the serving loop degrades to run-to-completion batching.
    fn supports_rowwise_decode_positions(&self) -> bool {
        false
    }

    /// This backend as a shareable trait object, when it can execute
    /// concurrently from several threads (`Sync` state, e.g. the
    /// pure-Rust reference backend). The pipeline uses it to run TP
    /// shard executions under `std::thread::scope`; thread-confined
    /// backends (PJRT's `Rc`-based handles) return `None` and shards run
    /// serially on the caller's thread.
    fn sync_view(&self) -> Option<&(dyn ExecutionBackend + Sync)> {
        None
    }

    /// Decode-step attention with the KV caches updated **in place**:
    /// writes only each row's new `[head_dim]` K/V slice at its position
    /// and reads the caches where they live, returning just the `[b, 1,
    /// h]` attention partial. This is the serving decode hot path — the
    /// value-passing [`Self::execute`] contract costs two full cache
    /// clones plus two full returned copies per call.
    ///
    /// The default implementation adapts backends bound to the
    /// functional artifact signature: it routes through
    /// [`Self::execute`] and moves the returned caches into place. Hot
    /// backends (the reference backend) override it with a true
    /// in-place kernel.
    fn execute_attn_decode_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        let b = x.dims.first().copied().unwrap_or(1);
        let pos_arg = match positions {
            DecodePositions::Scalar(p) => InputArg::ScalarI32(p),
            DecodePositions::PerRow(p) => InputArg::I32(p, vec![b]),
        };
        let mut outs = self.execute(
            artifact,
            &[
                InputArg::F32(x),
                InputArg::F32(k_cache),
                InputArg::F32(v_cache),
                pos_arg,
                InputArg::Weight(w.ln1),
                InputArg::Weight(w.wq),
                InputArg::Weight(w.wk),
                InputArg::Weight(w.wv),
                InputArg::Weight(w.wo),
            ],
        )?;
        if outs.len() != 3 {
            bail!("'{artifact}' returned {} outputs, expected (partial, k, v)", outs.len());
        }
        match (outs.pop(), outs.pop(), outs.pop()) {
            (Some(v), Some(k), Some(partial)) => {
                *v_cache = v;
                *k_cache = k;
                Ok(partial)
            }
            _ => bail!("'{artifact}' outputs vanished while unpacking (partial, k, v)"),
        }
    }

    /// Multi-position attention **scoring** with in-place KV writes: the
    /// verify half of speculative decoding. `x` is `[b, s, h]` — `s`
    /// proposed tokens per row appended after that row's cached prefix —
    /// and `positions[row]` is where row `row`'s *first* new KV entry
    /// lands (its cache depth before the call). The kernel writes all
    /// `s` new K/V slices per row at `positions[row] .. positions[row] +
    /// s` and attends each query token `i` causally over `[0,
    /// positions[row] + i]`, returning the `[b, s, h]` attention partial
    /// — one prefill-shaped pass instead of `s` decode iterations, which
    /// is what lets a target model score a whole draft proposal in one
    /// forward.
    ///
    /// The default implementation keeps every backend in contract by
    /// looping the proposal through [`Self::execute_attn_decode_inplace`]
    /// one position at a time — bit-identical results (each single-token
    /// step sees exactly the cache state the batched kernel would), just
    /// without the batching win. Hot backends (the reference backend)
    /// override it with a true multi-position kernel.
    fn execute_attn_score_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        if x.dims.len() != 3 {
            bail!("score input must be [b, s, h], got {:?}", x.dims);
        }
        let (b, s, h) = (x.dims[0], x.dims[1], x.dims[2]);
        if s == 0 {
            bail!("score input has zero proposed tokens");
        }
        let starts: Vec<i32> = match positions {
            DecodePositions::Scalar(p) => vec![p; b],
            DecodePositions::PerRow(p) => {
                if p.len() != b {
                    bail!("score positions: {} values for batch {b}", p.len());
                }
                p.to_vec()
            }
        };
        let uniform = starts.windows(2).all(|w| w[0] == w[1]);
        let mut out = Tensor { dims: vec![b, s, h], data: vec![0.0; b * s * h] };
        let mut xi = Tensor { dims: vec![b, 1, h], data: vec![0.0; b * h] };
        let mut step_pos = vec![0i32; b];
        for i in 0..s {
            for bi in 0..b {
                let src = (bi * s + i) * h;
                xi.data[bi * h..(bi + 1) * h].copy_from_slice(&x.data[src..src + h]);
                step_pos[bi] = starts[bi] + i as i32;
            }
            let pos = if uniform {
                DecodePositions::Scalar(step_pos[0])
            } else {
                DecodePositions::PerRow(&step_pos)
            };
            let partial =
                self.execute_attn_decode_inplace(artifact, &xi, k_cache, v_cache, pos, w)?;
            if partial.dims != [b, 1, h] {
                bail!(
                    "score adapter: decode step returned shape {:?}, expected [{b}, 1, {h}]",
                    partial.dims
                );
            }
            for bi in 0..b {
                let dst = (bi * s + i) * h;
                out.data[dst..dst + h].copy_from_slice(&partial.data[bi * h..(bi + 1) * h]);
            }
        }
        Ok(out)
    }

    /// Cumulative stage executions (hot-path metric).
    fn exec_count(&self) -> usize;
}

/// Which [`ExecutionBackend`] implementation to construct. `Copy` and
/// `Send` so service configs can hand it to worker threads, which each
/// build their own (possibly thread-confined) backend instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference numerics (always available).
    Reference,
    /// PJRT CPU client over AOT HLO artifacts (`pjrt` feature).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl Default for BackendKind {
    fn default() -> BackendKind {
        #[cfg(feature = "pjrt")]
        return BackendKind::Pjrt;
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Reference
    }
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Construct a backend re-using an already-parsed manifest and weight
/// store (what per-replica worker threads do).
pub fn make_backend(
    kind: BackendKind,
    dir: &Path,
    manifest: Manifest,
    weights: Arc<WeightStore>,
) -> Result<Box<dyn ExecutionBackend>> {
    #[cfg(not(feature = "pjrt"))]
    let _ = dir;
    match kind {
        BackendKind::Reference => Ok(Box::new(super::reference::ReferenceBackend::with_weights(
            manifest, weights,
        ))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(super::engine::ModelRuntime::with_weights(
            dir, manifest, weights,
        )?)),
    }
}

/// Load manifest + weights from an artifacts directory and construct the
/// requested backend.
pub fn load_backend(kind: BackendKind, dir: &Path) -> Result<Box<dyn ExecutionBackend>> {
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let weights = Arc::new(WeightStore::load(&dir.join("weights.bin"))?);
    make_backend(kind, dir, manifest, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_kind_matches_features() {
        #[cfg(not(feature = "pjrt"))]
        assert_eq!(BackendKind::default(), BackendKind::Reference);
        #[cfg(feature = "pjrt")]
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
        assert_eq!(BackendKind::Reference.name(), "reference");
    }
}
