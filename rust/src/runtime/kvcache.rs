//! Paged KV-cache bookkeeping: the logical layer of the block-pool KV
//! subsystem (vLLM-style paging, SNIPPETS §4 "KV Cache Optimization").
//!
//! This module owns *where cache rows live*, never their contents:
//!
//! * [`BlockPool`] — a fixed set of physical blocks (`block_tokens` KV
//!   rows each) with an explicit free list, per-block refcounts, and a
//!   reservation ledger so admission can promise a sequence its
//!   worst-case block budget up front (no mid-decode exhaustion, no
//!   over-commit).
//! * [`BlockTable`] — one per in-flight sequence: logical token
//!   positions → physical blocks (`pos / block_tokens` indexes the
//!   table, `pos % block_tokens` the row within the block). The same
//!   block id addresses every (stage, layer, shard) storage tensor.
//! * [`PrefixCache`] — maps hashed token-prefix chunks to already
//!   materialized blocks so concurrent requests sharing a prompt prefix
//!   share its first N blocks refcounted, with copy-on-write on the
//!   first divergent append ([`plan_append`]).
//!
//! The physical storage tensors (`[pool_blocks, heads, block_tokens,
//! head_dim]` per stage/layer/shard) live with the pipeline executor;
//! every function here returns plain bookkeeping (block ids, [`AppendOp`]
//! instructions) for the tensor layer to apply. That keeps this module
//! fully unit-testable without tensors and keeps the execution-kernel
//! contract untouched — paging changes block residency, and the dense
//! per-step gather in `coordinator::pipeline` feeds the kernels exactly
//! the caches they saw before.
//!
//! **Sharing correctness.** A KV row at position `i` depends only on
//! `tokens[0..=i]` (causal attention), and per-row decode computation is
//! independent of co-batched rows, so two sequences with identical token
//! prefixes have bit-identical KV for the shared positions — sharing the
//! backing blocks is invisible to the kernels. Shared *full* blocks are
//! never written again (appends only ever target the tail); a shared
//! partial tail block is copy-on-write before its first append. Cache
//! entries are verified token-by-token against a slab (plus parent-block
//! chaining), so a hash collision degrades to a miss, never a false
//! share. The cache holds no refcounts of its own: sharing happens among
//! concurrently live sequences, entries die with the last referencing
//! sequence, and the pool returns to fully-free when the session drains.
//!
//! **Who pays for a copy-on-write.** Either side of a share may be the
//! first to append into a shared partial tail — including the sequence
//! that originally materialized it, whose own block budget is exactly
//! sized and has no spare. So the COW block is earmarked on the *shared
//! block* rather than on any one sequence: each sharer converts one of
//! its reserved blocks into a [`BlockPool::earmark_cow`] credit at
//! admission, and whichever sequence diverges first spends a credit
//! ([`BlockPool::alloc_cow`]). Credits never run short (credit count ≥
//! refcount − 1 is an invariant: sharing adds one of each, a COW removes
//! one of each), and credits left over when the block frees return to
//! the admission budget automatically.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Default KV rows per block when [`KvPolicy::block_tokens`] is unset
/// (clamped to the model's `max_seq`).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Seed for the first chunk's [`PrefixCache::chain_key`] (FNV-1a offset
/// basis).
pub const PREFIX_HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Paged-KV configuration carried by a service / session.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPolicy {
    /// KV rows per block; `None` → [`DEFAULT_BLOCK_TOKENS`] clamped to
    /// `max_seq`. Smaller blocks waste fewer rows on short requests and
    /// share shorter prefixes, at more table entries per sequence.
    pub block_tokens: Option<usize>,
    /// Physical blocks in the session pool; `None` → the dense
    /// equivalent (`bucket * ceil(max_seq / block_tokens)`), which never
    /// defers an admission the dense backing would have accepted. Must
    /// hold at least one full sequence.
    pub pool_blocks: Option<usize>,
}

impl KvPolicy {
    /// The effective rows-per-block for a model context of `max_seq`.
    pub fn resolve_block_tokens(&self, max_seq: usize) -> usize {
        self.block_tokens.unwrap_or(DEFAULT_BLOCK_TOKENS).min(max_seq).max(1)
    }
}

/// Fixed-size physical block allocator with refcounts and a reservation
/// ledger. Blocks are identified by their dim-0 index into the storage
/// tensors. All methods are O(1); the free list is LIFO.
#[derive(Debug)]
pub struct BlockPool {
    block_tokens: usize,
    /// Per-block reference count; 0 ⇔ on the free list.
    rc: Vec<u32>,
    free: Vec<usize>,
    /// Blocks promised to admitted sequences but not yet allocated.
    /// Invariant: `reserved <= free.len()`, and `reserved` equals the
    /// sum of every live table's `reserved_left` plus every block's
    /// `cow_credit`.
    reserved: usize,
    /// Per-block copy-on-write earmarks: reserved blocks pledged to
    /// whichever sharer of this block diverges first. Invariant for a
    /// live block: `cow_credit >= rc - 1`.
    cow_credit: Vec<u32>,
    peak_used: usize,
}

impl BlockPool {
    pub fn new(num_blocks: usize, block_tokens: usize) -> Result<BlockPool> {
        if num_blocks == 0 || block_tokens == 0 {
            bail!("block pool needs >= 1 block of >= 1 tokens, got {num_blocks}x{block_tokens}");
        }
        Ok(BlockPool {
            block_tokens,
            rc: vec![0; num_blocks],
            // Reversed so allocation hands out block 0 first (LIFO pop):
            // deterministic layouts for tests and debugging.
            free: (0..num_blocks).rev().collect(),
            reserved: 0,
            cow_credit: vec![0; num_blocks],
            peak_used: 0,
        })
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn num_blocks(&self) -> usize {
        self.rc.len()
    }

    /// Blocks currently referenced by at least one sequence.
    pub fn used_blocks(&self) -> usize {
        self.rc.len() - self.free.len()
    }

    /// High-water mark of [`Self::used_blocks`] over the pool's lifetime
    /// (the capacity a right-sized pool would have needed).
    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    /// Free blocks not yet promised to anyone — the admission budget.
    pub fn available(&self) -> usize {
        self.free.len().saturating_sub(self.reserved)
    }

    /// Blocks needed to hold `tokens` KV rows.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens).max(1)
    }

    /// True when every block is unreferenced and no reservation is
    /// outstanding — the leak-test invariant after a session drains.
    pub fn is_fully_free(&self) -> bool {
        self.free.len() == self.rc.len() && self.reserved == 0
    }

    /// Refcount of `block` (0 ⇔ free).
    pub fn refcount(&self, block: usize) -> u32 {
        self.rc.get(block).copied().unwrap_or(0)
    }

    // lint: hot-path — pool bookkeeping runs per admission chunk and per
    // decode-step append; everything below is O(1) on preallocated
    // storage.

    /// Promise `n` blocks to a sequence being admitted. Returns `false`
    /// (and reserves nothing) when the unpromised free space cannot
    /// cover it — the caller defers admission instead of over-committing.
    pub fn try_reserve(&mut self, n: usize) -> bool {
        if self.available() >= n {
            self.reserved += n;
            true
        } else {
            false
        }
    }

    /// Return `n` promised-but-unallocated blocks to the admission
    /// budget (sequence retired, was cancelled, or shared its blocks).
    pub fn release_reservation(&mut self, n: usize) -> Result<()> {
        if n > self.reserved {
            bail!("releasing {n} reserved blocks but only {} are outstanding", self.reserved);
        }
        self.reserved -= n;
        Ok(())
    }

    /// Re-promise `n` blocks to a sequence that just popped tail blocks
    /// in a speculative rollback — the inverse of
    /// [`Self::release_reservation`]. Unlike [`Self::try_reserve`] this
    /// must succeed: the rollback released the very blocks that back the
    /// renewed promise (a popped owned block returns to the free list
    /// before its slot is reclaimed). A shortfall means the caller
    /// truncated into blocks another sequence still shares — its budget
    /// for that region was handed back at admission — and is surfaced as
    /// corruption rather than silently over-committing the pool.
    pub fn reclaim_reservation(&mut self, n: usize) -> Result<()> {
        if self.reserved + n > self.free.len() {
            bail!(
                "reclaiming {n} reserved blocks would over-commit the pool \
                 ({} free, {} already reserved): rollback truncated into shared blocks",
                self.free.len(),
                self.reserved
            );
        }
        self.reserved += n;
        Ok(())
    }

    /// Allocate one block against an outstanding reservation (rc = 1).
    pub fn alloc_reserved(&mut self) -> Result<usize> {
        if self.reserved == 0 {
            bail!("block allocation without a reservation");
        }
        let Some(block) = self.free.pop() else {
            bail!("pool corrupt: {} blocks reserved with an empty free list", self.reserved);
        };
        self.reserved -= 1;
        self.rc[block] = 1;
        let used = self.used_blocks();
        if used > self.peak_used {
            self.peak_used = used;
        }
        Ok(block)
    }

    /// Convert one reserved block into a copy-on-write credit on `block`.
    /// Called when an admission shares a live partial tail block: the
    /// sharer has consumed one slot of its own budget
    /// ([`BlockTable::use_reservation`]) and pledges it here, where any
    /// sharer's first divergent append can spend it ([`Self::alloc_cow`]).
    pub fn earmark_cow(&mut self, block: usize) -> Result<()> {
        if self.refcount(block) == 0 {
            bail!("copy-on-write earmark on free or out-of-range block {block}");
        }
        if self.reserved == 0 {
            bail!("copy-on-write earmark without an outstanding reservation");
        }
        self.cow_credit[block] += 1;
        Ok(())
    }

    /// Copy-on-write credits currently earmarked on `block`.
    pub fn cow_credits(&self, block: usize) -> u32 {
        self.cow_credit.get(block).copied().unwrap_or(0)
    }

    /// Allocate the copy-on-write destination for shared block `src`,
    /// spending one of `src`'s earmarked credits (rc = 1). A shared
    /// block always carries at least `rc - 1` credits, so this cannot
    /// fail for a genuinely shared tail — an empty purse means corrupted
    /// bookkeeping.
    pub fn alloc_cow(&mut self, src: usize) -> Result<usize> {
        if self.cow_credits(src) == 0 {
            bail!("copy-on-write of block {src} without an earmarked credit");
        }
        if self.reserved == 0 {
            bail!("pool corrupt: cow credit on block {src} with no reservation backing it");
        }
        let Some(block) = self.free.pop() else {
            bail!("pool corrupt: {} blocks reserved with an empty free list", self.reserved);
        };
        self.cow_credit[src] -= 1;
        self.reserved -= 1;
        self.rc[block] = 1;
        let used = self.used_blocks();
        if used > self.peak_used {
            self.peak_used = used;
        }
        Ok(block)
    }

    /// Add a reference to a live block (prefix-cache share).
    pub fn retain(&mut self, block: usize) -> Result<()> {
        if self.refcount(block) == 0 {
            bail!("retain of free or out-of-range block {block}");
        }
        self.rc[block] += 1;
        Ok(())
    }

    /// Drop a reference; returns `true` when the block was freed back to
    /// the pool (the caller must then forget any prefix-cache entry).
    /// Unspent copy-on-write credits on a freed block return to the
    /// admission budget (the divergence they covered can no longer
    /// happen).
    pub fn release(&mut self, block: usize) -> Result<bool> {
        if self.refcount(block) == 0 {
            bail!("double free of block {block}");
        }
        self.rc[block] -= 1;
        if self.rc[block] == 0 {
            let leftover = std::mem::take(&mut self.cow_credit[block]) as usize;
            if leftover > self.reserved {
                bail!("pool corrupt: {leftover} cow credits on block {block} exceed the ledger");
            }
            self.reserved -= leftover;
            self.free.push(block);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    // lint: hot-path-end
}

/// Logical-position → physical-block map for one in-flight sequence,
/// plus the sequence's remaining block reservation. `pos /
/// block_tokens` indexes [`Self::blocks`]; appends only ever extend or
/// rewrite the tail.
#[derive(Debug, Default)]
pub struct BlockTable {
    blocks: Vec<usize>,
    /// Reserved-but-unallocated blocks still owed to this sequence.
    reserved_left: usize,
}

impl BlockTable {
    /// A table with room for `cap` blocks (one full sequence), so
    /// steady-state admission pushes never reallocate.
    pub fn with_block_capacity(cap: usize) -> BlockTable {
        BlockTable { blocks: Vec::with_capacity(cap), reserved_left: 0 }
    }

    pub fn blocks(&self) -> &[usize] {
        &self.blocks
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn reserved_left(&self) -> usize {
        self.reserved_left
    }

    /// Start a sequence with a `reserved` block budget. The table must
    /// be empty (the previous occupant fully released).
    pub fn begin(&mut self, reserved: usize) -> Result<()> {
        if !self.blocks.is_empty() || self.reserved_left != 0 {
            bail!(
                "table still holds {} blocks / {} reservations from the previous occupant",
                self.blocks.len(),
                self.reserved_left
            );
        }
        self.reserved_left = reserved;
        Ok(())
    }

    /// Record one block of the budget as allocated (or as permanently
    /// shared, for full prefix-cache hits that can never be written).
    pub fn use_reservation(&mut self) -> Result<()> {
        if self.reserved_left == 0 {
            bail!("sequence exceeded its reserved block budget");
        }
        self.reserved_left -= 1;
        Ok(())
    }

    pub fn push(&mut self, block: usize) {
        self.blocks.push(block);
    }

    /// Replace the tail block (copy-on-write divergence).
    pub fn set_tail(&mut self, block: usize) -> Result<()> {
        match self.blocks.last_mut() {
            Some(tail) => {
                *tail = block;
                Ok(())
            }
            None => bail!("copy-on-write on an empty block table"),
        }
    }

    /// Pop the tail block during a speculative rollback, restoring one
    /// slot of the sequence's own budget (a truncated sequence may grow
    /// back to its admission-time worst case). The caller must mirror
    /// the restore on the pool side: release the popped block and then
    /// [`BlockPool::reclaim_reservation`] in that order, so the freed
    /// block re-enters the free list before the promise against it is
    /// renewed.
    pub fn pop_tail_reclaim(&mut self) -> Option<usize> {
        let block = self.blocks.pop()?;
        self.reserved_left += 1;
        Some(block)
    }

    /// Clear the table and hand back the unused reservation count (the
    /// caller releases the blocks themselves first, via the pool).
    pub fn finish(&mut self) -> usize {
        self.blocks.clear();
        std::mem::take(&mut self.reserved_left)
    }
}

/// Data movement the tensor layer must perform for one decode append
/// (planned by [`plan_append`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppendOp {
    /// Write the new KV row at `row` of `block` (in place).
    Write { block: usize, row: usize },
    /// Copy-on-write: duplicate rows `[0, copy_rows)` of `src` into the
    /// freshly owned `block` in every storage tensor, then write the new
    /// row at `row` of `block`. `src` stays live for its other refs.
    CowWrite { src: usize, block: usize, copy_rows: usize, row: usize },
}

// lint: hot-path — the per-row per-step block-table append path: O(1)
// bookkeeping, no allocation (tables are pre-sized, the free list pops).

/// Plan the append of one KV row at logical position `pos`: extend the
/// table with a fresh block at a block boundary, write the tail in
/// place when this sequence owns it, or copy-on-write a shared tail
/// before its first divergent append. Fresh blocks draw on the
/// sequence's own reservation; a COW block draws on the credits
/// earmarked on the shared tail at admission. Either way a planned
/// append cannot fail for lack of blocks — exhaustion here means
/// corrupted bookkeeping and is surfaced as an error.
pub fn plan_append(pool: &mut BlockPool, table: &mut BlockTable, pos: usize) -> Result<AppendOp> {
    let bt = pool.block_tokens();
    let idx = pos / bt;
    let row = pos % bt;
    if idx == table.len() {
        if row != 0 {
            bail!("append at position {pos} would skip rows in a fresh block");
        }
        table.use_reservation()?;
        let block = pool.alloc_reserved()?;
        table.push(block);
        return Ok(AppendOp::Write { block, row });
    }
    if idx + 1 != table.len() {
        bail!("append at position {pos} is not at the tail of a {}-block table", table.len());
    }
    let Some(&tail) = table.blocks().last() else {
        bail!("append at position {pos} into an empty block table");
    };
    if pool.refcount(tail) > 1 {
        // Shared tail: diverge onto an owned copy, spending one of the
        // credits the sharers earmarked on the block at admission — the
        // diverger's own budget never covered this (the original
        // materializer's budget is exactly sized), which is why the
        // earmark lives on the block and not in any one table.
        let fresh = pool.alloc_cow(tail)?;
        if pool.release(tail)? {
            bail!("copy-on-write source block {tail} freed under a shared refcount");
        }
        table.set_tail(fresh)?;
        Ok(AppendOp::CowWrite { src: tail, block: fresh, copy_rows: row, row })
    } else {
        Ok(AppendOp::Write { block: tail, row })
    }
}

// lint: hot-path-end

#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    block: usize,
    /// The block backing the previous chunk of the same prefix (`None`
    /// for chunk 0). Verified on lookup so an entry can only hit for the
    /// exact full prefix it was inserted under.
    parent: Option<usize>,
    /// Memoized greedy first token of the prompt whose *final* chunk
    /// this entry backs ([`PrefixCache::memo_first_token`]). Greedy
    /// prefill is deterministic, so a later admission whose every chunk
    /// hits the chain ending at this entry can skip its forward pass
    /// and emit this token directly.
    first_token: Option<i32>,
}

/// Token-prefix → block cache. Keys are chained FNV-1a hashes of the
/// prompt's `block_tokens`-sized chunks; every hit is verified against
/// the stored tokens (slab-backed, no allocation) and the parent-block
/// chain, so collisions degrade to misses. Entries are evicted the
/// moment their block returns to the free list ([`Self::forget`]); the
/// cache itself holds no references.
#[derive(Debug)]
pub struct PrefixCache {
    block_tokens: usize,
    map: HashMap<u64, PrefixEntry>,
    /// Reverse map: block id → its cache key, for O(1) invalidation.
    by_block: Vec<Option<u64>>,
    /// Verification slab: `block * block_tokens ..` holds the chunk's
    /// tokens (length in `lens`).
    tokens: Vec<i32>,
    lens: Vec<u32>,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new(num_blocks: usize, block_tokens: usize) -> PrefixCache {
        PrefixCache {
            block_tokens,
            // At most one entry per block: with_capacity up front keeps
            // steady-state inserts rehash-free.
            map: HashMap::with_capacity(num_blocks),
            by_block: vec![None; num_blocks],
            tokens: vec![0; num_blocks * block_tokens],
            lens: vec![0; num_blocks],
            hits: 0,
            misses: 0,
        }
    }

    /// Chained chunk key: fold `chunk_idx`, the chunk length, and every
    /// token into the previous chunk's key (FNV-1a). Seed chunk 0 with
    /// [`PREFIX_HASH_SEED`].
    pub fn chain_key(prev: u64, chunk_idx: usize, chunk: &[i32]) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = prev;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(PRIME);
            }
        };
        mix(chunk_idx as u64);
        mix(chunk.len() as u64);
        for &t in chunk {
            mix(t as u32 as u64);
        }
        h
    }

    // lint: hot-path — per-chunk admission lookup: one hash-map probe
    // plus a slab compare, no allocation.

    /// Resolve `chunk` (at chain key `key`, following the block that
    /// backed the previous chunk) to a live shared block. Token and
    /// parent verification make a hit exact; the caller must `retain`
    /// the returned block.
    pub fn lookup(&mut self, key: u64, parent: Option<usize>, chunk: &[i32]) -> Option<usize> {
        let found = match self.map.get(&key) {
            Some(e)
                if e.parent == parent
                    && self.lens[e.block] as usize == chunk.len()
                    && {
                        let start = e.block * self.block_tokens;
                        &self.tokens[start..start + chunk.len()] == chunk
                    } =>
            {
                Some(e.block)
            }
            _ => None,
        };
        if found.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        found
    }

    // lint: hot-path-end

    /// Publish `block` as the backing of `chunk` under `key`. Called at
    /// admission for freshly materialized prompt blocks (copy-on-write
    /// copies are deliberately not re-published; the original entry
    /// stays valid).
    pub fn insert(&mut self, key: u64, block: usize, parent: Option<usize>, chunk: &[i32]) {
        debug_assert!(chunk.len() <= self.block_tokens);
        if let Some(old_key) = self.by_block[block] {
            if old_key != key && self.map.get(&old_key).is_some_and(|e| e.block == block) {
                self.map.remove(&old_key);
            }
        }
        if let Some(prev) = self.map.insert(key, PrefixEntry { block, parent, first_token: None }) {
            if prev.block != block && self.by_block[prev.block] == Some(key) {
                self.by_block[prev.block] = None;
                self.lens[prev.block] = 0;
            }
        }
        self.by_block[block] = Some(key);
        self.lens[block] = chunk.len() as u32;
        let start = block * self.block_tokens;
        self.tokens[start..start + chunk.len()].copy_from_slice(chunk);
    }

    /// Memoize the greedy first token of the prompt whose final chunk
    /// the entry at `key` backs. No-op if the entry was evicted between
    /// admission and the prefill pass. A fresh [`Self::insert`] under
    /// the same key resets the memo, so a stored token always describes
    /// the entry's current (verified) chain.
    pub fn memo_first_token(&mut self, key: u64, tok: i32) {
        if let Some(e) = self.map.get_mut(&key) {
            e.first_token = Some(tok);
        }
    }

    /// The memoized first token for the prompt chain ending at `key`,
    /// if one was recorded. Only meaningful right after every chunk of
    /// the prompt hit [`Self::lookup`] — the chained verification is
    /// what ties `key` to the exact full prompt.
    pub fn first_token(&self, key: u64) -> Option<i32> {
        self.map.get(&key).and_then(|e| e.first_token)
    }

    /// Invalidate whatever entry `block` backs — called when the pool
    /// frees it, before the block can be recycled with new contents.
    pub fn forget(&mut self, block: usize) {
        if let Some(key) = self.by_block[block].take() {
            if self.map.get(&key).is_some_and(|e| e.block == block) {
                self.map.remove(&key);
            }
        }
        self.lens[block] = 0;
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lifetime chunk-lookup hits.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lifetime chunk-lookup misses.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_resolves_block_tokens() {
        assert_eq!(KvPolicy::default().resolve_block_tokens(160), DEFAULT_BLOCK_TOKENS);
        assert_eq!(KvPolicy::default().resolve_block_tokens(8), 8);
        let p = KvPolicy { block_tokens: Some(4), pool_blocks: None };
        assert_eq!(p.resolve_block_tokens(160), 4);
        let zero = KvPolicy { block_tokens: Some(0), pool_blocks: None };
        assert_eq!(zero.resolve_block_tokens(160), 1, "zero clamps up, never panics");
    }

    #[test]
    fn pool_reserve_alloc_release_roundtrip() {
        let mut p = BlockPool::new(4, 8).unwrap();
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(8), 1);
        assert_eq!(p.blocks_for(9), 2);
        assert_eq!(p.blocks_for(0), 1, "even an empty sequence charges one block");
        assert!(p.is_fully_free());
        assert_eq!(p.available(), 4);

        assert!(p.try_reserve(3));
        assert_eq!(p.available(), 1);
        assert!(!p.try_reserve(2), "over-reservation must be refused, not panic");
        assert!(p.try_reserve(1));
        assert_eq!(p.available(), 0);

        let a = p.alloc_reserved().unwrap();
        let b = p.alloc_reserved().unwrap();
        assert_eq!((a, b), (0, 1), "deterministic low-first allocation");
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.refcount(a), 1);

        // Sharing: rc 2, releases in either order; only the last frees.
        p.retain(a).unwrap();
        assert_eq!(p.refcount(a), 2);
        assert!(!p.release(a).unwrap());
        assert!(p.release(a).unwrap());
        assert!(p.release(a).is_err(), "double free is surfaced");

        assert!(p.release(b).unwrap());
        p.release_reservation(2).unwrap();
        assert!(p.release_reservation(1).is_err(), "reservation ledger underflow is surfaced");
        assert!(p.is_fully_free());
        assert_eq!(p.peak_used_blocks(), 2);
    }

    #[test]
    fn alloc_requires_reservation_and_retain_requires_live_block() {
        let mut p = BlockPool::new(2, 4).unwrap();
        assert!(p.alloc_reserved().is_err());
        assert!(p.retain(0).is_err(), "free block cannot be retained");
        assert!(p.retain(99).is_err());
        assert!(BlockPool::new(0, 4).is_err());
        assert!(BlockPool::new(4, 0).is_err());
    }

    /// The speculative-rollback primitives: popping a tail block restores
    /// the sequence's own budget slot, release-then-reclaim restores the
    /// pool ledger, and reclaiming without free backing (a shared block
    /// that stayed live) is refused as corruption.
    #[test]
    fn rollback_pop_release_reclaim_restores_budget() {
        let mut pool = BlockPool::new(2, 4).unwrap();
        let mut table = BlockTable::with_block_capacity(2);
        assert!(pool.try_reserve(2));
        table.begin(2).unwrap();
        for _ in 0..2 {
            table.use_reservation().unwrap();
            let b = pool.alloc_reserved().unwrap();
            table.push(b);
        }
        assert_eq!(table.reserved_left(), 0);
        assert_eq!(pool.available(), 0);

        // Roll the tail block back: pop → release → reclaim.
        let popped = table.pop_tail_reclaim().unwrap();
        assert_eq!(popped, 1);
        assert_eq!(table.reserved_left(), 1);
        assert!(pool.release(popped).unwrap(), "owned tail frees on release");
        pool.reclaim_reservation(1).unwrap();
        assert_eq!(pool.available(), 0, "the freed block backs the renewed promise");

        // The budget is spendable again: re-extend into a fresh block.
        table.use_reservation().unwrap();
        let again = pool.alloc_reserved().unwrap();
        table.push(again);
        assert_eq!(table.len(), 2);

        // Full teardown drains the pool.
        for &b in table.blocks() {
            pool.release(b).unwrap();
        }
        pool.release_reservation(table.finish()).unwrap();
        assert!(pool.is_fully_free());
        assert!(table.pop_tail_reclaim().is_none(), "empty table has no tail");

        // Reclaim without free backing is surfaced, not over-committed:
        // both blocks allocated and one still live elsewhere.
        let mut p2 = BlockPool::new(1, 4).unwrap();
        assert!(p2.try_reserve(1));
        let b0 = p2.alloc_reserved().unwrap();
        p2.retain(b0).unwrap();
        assert!(!p2.release(b0).unwrap(), "still shared, stays live");
        assert!(
            p2.reclaim_reservation(1).is_err(),
            "no free block backs the promise while the popped block is shared"
        );
    }

    /// Drive a sequence's whole block lifecycle through [`plan_append`]:
    /// boundary allocation, in-place tail writes, and exact reservation
    /// accounting, ending with the pool fully free.
    #[test]
    fn plan_append_extends_and_writes_in_place() {
        let mut pool = BlockPool::new(4, 4).unwrap();
        let mut table = BlockTable::with_block_capacity(4);
        // "Prompt" of 6 tokens (1 full + 1 partial block), budget for 3.
        assert!(pool.try_reserve(3));
        table.begin(3).unwrap();
        for _ in 0..2 {
            table.use_reservation().unwrap();
            let b = pool.alloc_reserved().unwrap();
            table.push(b);
        }
        // Appends at 6, 7 land in the owned tail; 8 opens a new block.
        assert_eq!(plan_append(&mut pool, &mut table, 6).unwrap(), AppendOp::Write {
            block: 1,
            row: 2
        });
        assert_eq!(plan_append(&mut pool, &mut table, 7).unwrap(), AppendOp::Write {
            block: 1,
            row: 3
        });
        assert_eq!(plan_append(&mut pool, &mut table, 8).unwrap(), AppendOp::Write {
            block: 2,
            row: 0
        });
        assert_eq!(table.reserved_left(), 0);
        assert!(
            plan_append(&mut pool, &mut table, 9).is_ok(),
            "in-place tail writes need no reservation"
        );
        // Off-tail and row-skipping appends are corrupted bookkeeping.
        assert!(plan_append(&mut pool, &mut table, 2).is_err());
        assert!(plan_append(&mut pool, &mut table, 17).is_err());

        for &b in table.blocks() {
            assert!(pool.release(b).unwrap());
        }
        pool.release_reservation(table.finish()).unwrap();
        assert!(pool.is_fully_free(), "no leaked blocks or reservations");
    }

    #[test]
    fn plan_append_cow_diverges_shared_tail_without_freeing_source() {
        let mut pool = BlockPool::new(4, 4).unwrap();
        // Owner A materializes a partial tail block (2 of 4 rows) with an
        // exactly-sized budget: 1 block for the prompt + 1 fresh append
        // block, no spare for a copy-on-write it cannot foresee.
        let mut a = BlockTable::with_block_capacity(4);
        assert!(pool.try_reserve(2));
        a.begin(2).unwrap();
        a.use_reservation().unwrap();
        let shared = pool.alloc_reserved().unwrap();
        a.push(shared);
        // B shares it (prefix hit): B consumes one of its own reserved
        // blocks and pledges it to the block as the COW credit.
        let mut b = BlockTable::with_block_capacity(4);
        assert!(pool.try_reserve(2));
        b.begin(2).unwrap();
        pool.retain(shared).unwrap();
        b.push(shared);
        b.use_reservation().unwrap();
        pool.earmark_cow(shared).unwrap();
        assert_eq!(pool.cow_credits(shared), 1);

        // A appends first — the forced-COW case: A's own budget never
        // covered this divergence, so the block's credit pays for it.
        let op = plan_append(&mut pool, &mut a, 2).unwrap();
        let AppendOp::CowWrite { src, block, copy_rows, row } = op else {
            panic!("shared tail must copy-on-write, got {op:?}");
        };
        assert_eq!((src, copy_rows, row), (shared, 2, 2));
        assert_ne!(block, shared);
        assert_eq!(a.blocks(), &[block]);
        assert_eq!(pool.refcount(shared), 1, "B still holds the source");
        assert_eq!(pool.refcount(block), 1);
        assert_eq!(pool.cow_credits(shared), 0, "the divergence spent the credit");
        assert_eq!(a.reserved_left(), 1, "A's own budget is untouched by the COW");

        // B appends next: sole owner now, writes in place.
        assert_eq!(plan_append(&mut pool, &mut b, 2).unwrap(), AppendOp::Write {
            block: shared,
            row: 2
        });

        // Retire both; every block and reservation comes back.
        for t in [&mut a, &mut b] {
            for &blk in t.blocks() {
                pool.release(blk).unwrap();
            }
            pool.release_reservation(t.finish()).unwrap();
        }
        assert!(pool.is_fully_free());
    }

    #[test]
    fn cow_credit_lifecycle_and_leftover_release() {
        let mut pool = BlockPool::new(3, 4).unwrap();
        assert!(pool.earmark_cow(0).is_err(), "free block cannot carry a credit");
        assert!(pool.try_reserve(2));
        let mut owner = BlockTable::with_block_capacity(2);
        owner.begin(2).unwrap();
        owner.use_reservation().unwrap();
        let shared = pool.alloc_reserved().unwrap();
        owner.push(shared);
        assert!(
            pool.alloc_cow(shared).is_err(),
            "a COW without an earmarked credit is corrupted bookkeeping"
        );
        // A sharer pledges its reservation to the block, then retires
        // without ever diverging: the credit outlives the sharer...
        let mut sharer = BlockTable::with_block_capacity(2);
        assert!(pool.try_reserve(1));
        sharer.begin(1).unwrap();
        pool.retain(shared).unwrap();
        sharer.push(shared);
        sharer.use_reservation().unwrap();
        pool.earmark_cow(shared).unwrap();
        assert!(!pool.release(shared).unwrap());
        pool.release_reservation(sharer.finish()).unwrap();
        assert_eq!(pool.cow_credits(shared), 1, "credit survives the sharer");
        assert_eq!(pool.available(), 0, "the credit still holds a block hostage");
        // ...and returns to the admission budget when the block frees.
        assert!(pool.release(shared).unwrap());
        assert_eq!(pool.cow_credits(shared), 0);
        pool.release_reservation(owner.finish()).unwrap();
        assert!(pool.is_fully_free(), "leftover credits must not leak reservations");
    }

    #[test]
    fn table_begin_rejects_dirty_state() {
        let mut t = BlockTable::with_block_capacity(2);
        t.begin(2).unwrap();
        assert!(t.begin(1).is_err(), "reservation left over");
        t.finish();
        t.begin(1).unwrap();
        t.use_reservation().unwrap();
        assert!(t.use_reservation().is_err(), "budget exceeded is surfaced");
        t.push(0);
        t.set_tail(3).unwrap();
        assert_eq!(t.blocks(), &[3]);
        assert!(t.begin(1).is_err(), "blocks left over");
        assert_eq!(t.finish(), 0);
        assert!(t.is_empty());
        let mut empty = BlockTable::default();
        assert!(empty.set_tail(0).is_err());
    }

    #[test]
    fn prefix_cache_verifies_tokens_parent_and_length() {
        let mut c = PrefixCache::new(4, 4);
        let chunk0 = [1, 2, 3, 4];
        let chunk1 = [5, 6];
        let k0 = PrefixCache::chain_key(PREFIX_HASH_SEED, 0, &chunk0);
        let k1 = PrefixCache::chain_key(k0, 1, &chunk1);
        assert!(c.lookup(k0, None, &chunk0).is_none(), "cold cache misses");
        c.insert(k0, 0, None, &chunk0);
        c.insert(k1, 1, Some(0), &chunk1);
        assert_eq!(c.len(), 2);

        assert_eq!(c.lookup(k0, None, &chunk0), Some(0));
        assert_eq!(c.lookup(k1, Some(0), &chunk1), Some(1));
        // Same key, different parent: a different prefix reached the
        // same hash — must miss, never falsely share.
        assert!(c.lookup(k1, Some(2), &chunk1).is_none());
        assert!(c.lookup(k1, None, &chunk1).is_none());
        // Key collision with different tokens: verification catches it.
        assert!(c.lookup(k0, None, &[9, 9, 9, 9]).is_none());
        assert!(c.lookup(k0, None, &[1, 2, 3]).is_none(), "length mismatch");
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 5);

        // Chain keys are position- and length-sensitive.
        assert_ne!(PrefixCache::chain_key(PREFIX_HASH_SEED, 0, &chunk0), k1);
        assert_ne!(PrefixCache::chain_key(PREFIX_HASH_SEED, 1, &chunk0), k0);
    }

    #[test]
    fn prefix_cache_forget_and_reinsert_recycled_block() {
        let mut c = PrefixCache::new(4, 4);
        let chunk = [7, 8, 9];
        let k = PrefixCache::chain_key(PREFIX_HASH_SEED, 0, &chunk);
        c.insert(k, 2, None, &chunk);
        assert_eq!(c.lookup(k, None, &chunk), Some(2));
        c.forget(2);
        assert!(c.lookup(k, None, &chunk).is_none(), "freed block's entry is gone");
        assert!(c.is_empty());
        // The recycled block can back a different chunk.
        let other = [1, 1];
        let k2 = PrefixCache::chain_key(PREFIX_HASH_SEED, 0, &other);
        c.insert(k2, 2, None, &other);
        assert_eq!(c.lookup(k2, None, &other), Some(2));
        // Re-keying the same block drops its old entry.
        c.insert(k, 2, None, &chunk);
        assert!(c.lookup(k2, None, &other).is_none());
        assert_eq!(c.lookup(k, None, &chunk), Some(2));
        assert_eq!(c.len(), 1);
        // forget of a block with no entry is a no-op.
        c.forget(3);
    }

    #[test]
    fn prefix_cache_first_token_memo_lifecycle() {
        let mut c = PrefixCache::new(4, 4);
        let chunk = [1, 2, 3, 4];
        let k = PrefixCache::chain_key(PREFIX_HASH_SEED, 0, &chunk);
        c.insert(k, 0, None, &chunk);
        assert_eq!(c.first_token(k), None, "fresh entry carries no memo");
        c.memo_first_token(k, 42);
        assert_eq!(c.first_token(k), Some(42));
        // Memo on an absent key is a no-op (entry evicted mid-pass).
        c.memo_first_token(99, 7);
        assert_eq!(c.first_token(99), None);
        // Re-inserting the key resets the memo with the new content.
        c.insert(k, 1, None, &chunk);
        assert_eq!(c.first_token(k), None, "re-insert resets the memo");
        // forget drops the memo along with the entry.
        c.memo_first_token(k, 43);
        c.forget(1);
        assert_eq!(c.first_token(k), None);
    }
}
