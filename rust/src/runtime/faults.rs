//! Deterministic fault injection for the serving path.
//!
//! HexGen's premise is serving from cheap, decentralized, heterogeneous
//! pools — exactly the machines that die, stall, and flake under load.
//! This module makes those failures *reproducible*: a [`FaultPlan`] is a
//! seeded, serializable schedule of faults, and [`FaultInjectingBackend`]
//! wraps any [`ExecutionBackend`] and fires them at exact call boundaries
//! so every recovery path (failover, circuit breaker, deadline expiry)
//! is testable in plain `cargo test` and from `serve --fault-plan FILE`.
//!
//! A plan is a list of [`FaultSpec`]s. Each spec targets a replica (or
//! all), one backend entry point (or any), and a trigger over that
//! spec's own 1-based call counter:
//!
//! * `nth: N` — fire exactly on the N-th matching call;
//! * `after: K` — fire on every matching call past the K-th;
//! * `probability: p` — fire with probability `p`, derived from the plan
//!   seed + spec index + call number (deterministic regardless of thread
//!   interleaving);
//! * `until: U` — bounds `after`/`probability` windows to calls ≤ U, so
//!   a replica can fault for a while and then recover (what the breaker
//!   half-open probe needs to observe).
//!
//! Fault kinds: `error` (the call fails — the worker sees a replica
//! fault), `panic` (a TP shard thread panics; degraded to an error on
//! the session thread, where an uncaught panic would kill the worker
//! outright instead of exercising recovery), and `stall` (the call
//! sleeps D ms then proceeds — a slow replica, not a broken one, which
//! is what deadline enforcement has to absorb).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::ThreadId;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::backend::{
    AttnShardWeights, BackendKind, DecodePositions, ExecutionBackend, InputArg,
};
use super::manifest::Manifest;
use super::weights::{Tensor, WeightStore};

/// What happens when a fault fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The call returns an error; the worker sees a replica fault.
    Error,
    /// The call panics. Only TP shard threads actually panic (the
    /// pipeline catches the unwind and surfaces it as a typed error);
    /// on the session thread the panic is degraded to an error, since
    /// an uncaught panic there kills the worker instead of testing it.
    Panic,
    /// The call sleeps for `ms` milliseconds, then proceeds normally.
    Stall { ms: u64 },
}

/// Which backend entry point a spec applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    /// Every entry point below.
    Any,
    /// [`ExecutionBackend::execute`] (prefill and non-attention stages).
    Execute,
    /// [`ExecutionBackend::execute_attn_decode_inplace`] (decode steps).
    Decode,
    /// [`ExecutionBackend::execute_attn_score_inplace`] (speculative
    /// verification).
    Score,
}

impl FaultOp {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultOp::Any => "any",
            FaultOp::Execute => "execute",
            FaultOp::Decode => "decode",
            FaultOp::Score => "score",
        }
    }

    fn parse(s: &str) -> Result<FaultOp> {
        Ok(match s {
            "any" => FaultOp::Any,
            "execute" => FaultOp::Execute,
            "decode" => FaultOp::Decode,
            "score" => FaultOp::Score,
            other => bail!("unknown fault op '{other}' (any|execute|decode|score)"),
        })
    }
}

/// One scheduled fault: where it applies and when it fires.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Replica index, or `None` for every replica.
    pub replica: Option<usize>,
    /// Which backend entry point this spec counts and faults.
    pub op: FaultOp,
    /// Fire exactly on the N-th matching call (1-based).
    pub nth: Option<u64>,
    /// Fire on every matching call with number > K.
    pub after: Option<u64>,
    /// Upper bound on `after`/`probability` windows: calls past U never
    /// fire, so a replica can fault and then recover.
    pub until: Option<u64>,
    /// Fire with this probability, derived from the plan seed.
    pub probability: Option<f64>,
    /// What happens when the spec fires.
    pub kind: FaultKind,
    /// Free-form tag carried into the error/panic message.
    pub message: String,
}

impl FaultSpec {
    fn matches(&self, replica: usize, op: FaultOp) -> bool {
        self.replica.map_or(true, |r| r == replica)
            && (self.op == FaultOp::Any || self.op == op)
    }

    /// Whether the spec fires on its `n`-th matching call (1-based).
    fn fires(&self, n: u64, seed: u64, spec_idx: usize) -> bool {
        if let Some(nth) = self.nth {
            if n != nth {
                return false;
            }
        }
        if let Some(after) = self.after {
            if n <= after {
                return false;
            }
        }
        if let Some(until) = self.until {
            if n > until {
                return false;
            }
        }
        if let Some(p) = self.probability {
            if unit_from(seed, spec_idx, n) >= p {
                return false;
            }
        }
        // A spec with no trigger at all never fires; `FaultPlan::parse`
        // rejects such specs, but a hand-built one stays inert.
        self.nth.is_some() || self.after.is_some() || self.probability.is_some()
    }

    fn from_json(j: &Json) -> Result<FaultSpec> {
        let kind = match j.opt("kind").map(|k| k.as_str()).transpose()? {
            None | Some("error") => FaultKind::Error,
            Some("panic") => FaultKind::Panic,
            Some("stall") => FaultKind::Stall {
                ms: j
                    .get("stall_ms")
                    .context("fault kind 'stall' needs a 'stall_ms' field")?
                    .as_u64()?,
            },
            Some(other) => bail!("unknown fault kind '{other}' (error|panic|stall)"),
        };
        let spec = FaultSpec {
            replica: j.opt("replica").map(|v| v.as_usize()).transpose()?,
            op: match j.opt("op") {
                Some(v) => FaultOp::parse(v.as_str()?)?,
                None => FaultOp::Any,
            },
            nth: j.opt("nth").map(|v| v.as_u64()).transpose()?,
            after: j.opt("after").map(|v| v.as_u64()).transpose()?,
            until: j.opt("until").map(|v| v.as_u64()).transpose()?,
            probability: j.opt("probability").map(|v| v.as_f64()).transpose()?,
            kind,
            message: j
                .opt("message")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "injected fault".to_string()),
        };
        if spec.nth.is_none() && spec.after.is_none() && spec.probability.is_none() {
            bail!("fault spec needs at least one trigger: nth, after, or probability");
        }
        if let Some(p) = spec.probability {
            if !(0.0..=1.0).contains(&p) {
                bail!("fault probability {p} outside [0, 1]");
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(r) = self.replica {
            j.set("replica", Json::from(r));
        }
        j.set("op", Json::from(self.op.as_str()));
        if let Some(n) = self.nth {
            j.set("nth", Json::from(n));
        }
        if let Some(a) = self.after {
            j.set("after", Json::from(a));
        }
        if let Some(u) = self.until {
            j.set("until", Json::from(u));
        }
        if let Some(p) = self.probability {
            j.set("probability", Json::from(p));
        }
        match self.kind {
            FaultKind::Error => j.set("kind", Json::from("error")),
            FaultKind::Panic => j.set("kind", Json::from("panic")),
            FaultKind::Stall { ms } => {
                j.set("kind", Json::from("stall")).set("stall_ms", Json::from(ms))
            }
        };
        j.set("message", Json::from(self.message.as_str()));
        j
    }
}

/// A seeded, serializable schedule of faults — what `serve --fault-plan`
/// loads and `ServiceConfig.faults` carries.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Base seed for probabilistic specs.
    pub seed: u64,
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("fault plan: {e}"))?;
        let seed = match j.opt("seed") {
            Some(v) => v.as_u64()?,
            None => 0,
        };
        let faults = j
            .get("faults")
            .map_err(|e| anyhow::anyhow!("fault plan: {e}"))?
            .as_arr()
            .map_err(|e| anyhow::anyhow!("fault plan: {e}"))?
            .iter()
            .map(FaultSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan { seed, faults })
    }

    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path:?}"))?;
        FaultPlan::parse(&text).with_context(|| format!("parsing fault plan {path:?}"))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", Json::from(self.seed));
        j.set("faults", Json::Arr(self.faults.iter().map(FaultSpec::to_json).collect()));
        j
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform sample in [0, 1) keyed on (seed, spec index, call number) —
/// independent of thread interleaving, so storms replay exactly.
fn unit_from(seed: u64, spec_idx: usize, n: u64) -> f64 {
    let h = splitmix64(seed ^ splitmix64(((spec_idx as u64) << 32) ^ n));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// An [`ExecutionBackend`] wrapper that fires a [`FaultPlan`]'s faults
/// at exact call boundaries. Per-spec call counters live here and
/// survive session rebuilds (workers build their executor once), so an
/// `nth`-call fault fires once, not once per rebuilt session.
pub struct FaultInjectingBackend<B> {
    inner: B,
    replica: usize,
    plan: Arc<FaultPlan>,
    counters: Vec<AtomicU64>,
    /// The constructing (session) thread: `Panic` faults observed here
    /// degrade to errors; TP shard threads really panic (the pipeline
    /// catches the unwind and surfaces it as a replica fault).
    owner: ThreadId,
}

impl<B: ExecutionBackend + Sync> FaultInjectingBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>, replica: usize) -> FaultInjectingBackend<B> {
        let counters = plan.faults.iter().map(|_| AtomicU64::new(0)).collect();
        FaultInjectingBackend {
            inner,
            replica,
            plan,
            counters,
            owner: std::thread::current().id(),
        }
    }

    fn check(&self, op: FaultOp) -> Result<()> {
        for (i, spec) in self.plan.faults.iter().enumerate() {
            if !spec.matches(self.replica, op) {
                continue;
            }
            let n = self.counters[i].fetch_add(1, Ordering::Relaxed) + 1;
            if !spec.fires(n, self.plan.seed, i) {
                continue;
            }
            match spec.kind {
                FaultKind::Stall { ms } => std::thread::sleep(Duration::from_millis(ms)),
                FaultKind::Error => bail!(
                    "injected fault: {} (replica {}, {} call #{n})",
                    spec.message,
                    self.replica,
                    op.as_str()
                ),
                FaultKind::Panic => {
                    if std::thread::current().id() != self.owner {
                        panic!(
                            "injected fault: {} (replica {}, {} call #{n})",
                            spec.message,
                            self.replica,
                            op.as_str()
                        );
                    }
                    bail!(
                        "injected fault: {} (replica {}, {} call #{n}; \
                         panic degraded to error on the session thread)",
                        spec.message,
                        self.replica,
                        op.as_str()
                    );
                }
            }
        }
        Ok(())
    }
}

impl<B: ExecutionBackend + Sync> ExecutionBackend for FaultInjectingBackend<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn weights(&self) -> &Arc<WeightStore> {
        self.inner.weights()
    }

    fn execute(&self, artifact: &str, inputs: &[InputArg<'_>]) -> Result<Vec<Tensor>> {
        self.check(FaultOp::Execute)?;
        self.inner.execute(artifact, inputs)
    }

    fn supports_rowwise_decode_positions(&self) -> bool {
        self.inner.supports_rowwise_decode_positions()
    }

    fn sync_view(&self) -> Option<&(dyn ExecutionBackend + Sync)> {
        Some(self)
    }

    fn execute_attn_decode_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        self.check(FaultOp::Decode)?;
        self.inner
            .execute_attn_decode_inplace(artifact, x, k_cache, v_cache, positions, w)
    }

    fn execute_attn_score_inplace(
        &self,
        artifact: &str,
        x: &Tensor,
        k_cache: &mut Tensor,
        v_cache: &mut Tensor,
        positions: DecodePositions<'_>,
        w: &AttnShardWeights<'_>,
    ) -> Result<Tensor> {
        self.check(FaultOp::Score)?;
        self.inner
            .execute_attn_score_inplace(artifact, x, k_cache, v_cache, positions, w)
    }

    fn exec_count(&self) -> usize {
        self.inner.exec_count()
    }
}

/// Construct a fault-injecting backend re-using an already-parsed
/// manifest and weight store — the fault-plan counterpart of
/// [`super::backend::make_backend`]. Only the reference backend is
/// wrappable today: the wrapper fans TP shards out through `sync_view`,
/// which PJRT's thread-confined handles cannot provide.
pub fn make_fault_backend(
    kind: BackendKind,
    _dir: &Path,
    manifest: Manifest,
    weights: Arc<WeightStore>,
    plan: Arc<FaultPlan>,
    replica: usize,
) -> Result<Box<dyn ExecutionBackend>> {
    match kind {
        BackendKind::Reference => Ok(Box::new(FaultInjectingBackend::new(
            super::reference::ReferenceBackend::with_weights(manifest, weights),
            plan,
            replica,
        ))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => bail!("fault injection requires the reference backend"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(nth: Option<u64>, after: Option<u64>, until: Option<u64>) -> FaultSpec {
        FaultSpec {
            replica: None,
            op: FaultOp::Any,
            nth,
            after,
            until,
            probability: None,
            kind: FaultKind::Error,
            message: "t".to_string(),
        }
    }

    #[test]
    fn nth_fires_exactly_once() {
        let s = spec(Some(3), None, None);
        let fired: Vec<u64> = (1..=6).filter(|&n| s.fires(n, 0, 0)).collect();
        assert_eq!(fired, vec![3]);
    }

    #[test]
    fn after_fires_every_call_past_k_until_bound() {
        let s = spec(None, Some(2), Some(4));
        let fired: Vec<u64> = (1..=6).filter(|&n| s.fires(n, 0, 0)).collect();
        assert_eq!(fired, vec![3, 4]);
        let unbounded = spec(None, Some(2), None);
        let fired: Vec<u64> = (1..=6).filter(|&n| unbounded.fires(n, 0, 0)).collect();
        assert_eq!(fired, vec![3, 4, 5, 6]);
    }

    #[test]
    fn probability_is_deterministic_and_roughly_calibrated() {
        let mut s = spec(None, None, None);
        s.probability = Some(0.25);
        let a: Vec<bool> = (1..=4000).map(|n| s.fires(n, 42, 1)).collect();
        let b: Vec<bool> = (1..=4000).map(|n| s.fires(n, 42, 1)).collect();
        assert_eq!(a, b, "same seed must replay identically");
        let hits = a.iter().filter(|&&x| x).count();
        assert!((800..=1200).contains(&hits), "p=0.25 over 4000 draws hit {hits}");
        let c: Vec<bool> = (1..=4000).map(|n| s.fires(n, 43, 1)).collect();
        assert_ne!(a, c, "different seed must differ");
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan {
            seed: 7,
            faults: vec![
                FaultSpec {
                    replica: Some(1),
                    op: FaultOp::Decode,
                    nth: Some(5),
                    after: None,
                    until: None,
                    probability: None,
                    kind: FaultKind::Error,
                    message: "boom".to_string(),
                },
                FaultSpec {
                    replica: None,
                    op: FaultOp::Any,
                    nth: None,
                    after: Some(10),
                    until: Some(20),
                    probability: Some(0.5),
                    kind: FaultKind::Stall { ms: 30 },
                    message: "slow".to_string(),
                },
            ],
        };
        let round = FaultPlan::parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(round, plan);
    }

    #[test]
    fn parse_rejects_triggerless_and_bad_specs() {
        assert!(FaultPlan::parse(r#"{"faults": [{"kind": "error"}]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"faults": [{"nth": 1, "kind": "stall"}]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"faults": [{"nth": 1, "op": "frobnicate"}]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"faults": [{"probability": 1.5}]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"faults": []}"#).unwrap().faults.is_empty());
    }

    #[test]
    fn spec_scopes_to_replica_and_op() {
        let s = FaultSpec {
            replica: Some(2),
            op: FaultOp::Decode,
            ..spec(Some(1), None, None)
        };
        assert!(s.matches(2, FaultOp::Decode));
        assert!(!s.matches(1, FaultOp::Decode));
        assert!(!s.matches(2, FaultOp::Execute));
        let any = spec(Some(1), None, None);
        assert!(any.matches(0, FaultOp::Score) && any.matches(7, FaultOp::Execute));
    }
}
