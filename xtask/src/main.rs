//! `cargo xtask` — project automation. The only subcommand today is
//! `lint`, which enforces HexGen's serving-path invariants over
//! `rust/src` (see `rules.rs` for the catalog and `rust/README.md`
//! § Correctness tooling for the policy).
//!
//! Exit status: 0 when the tree is clean, 1 when any diagnostic fires
//! (including misused `lint:` markers), 2 on usage or I/O errors.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Diagnostics plus allow notes for a whole tree.
#[derive(Debug, Default)]
struct TreeReport {
    /// `(rel_path, diagnostic)` pairs, in path order.
    diagnostics: Vec<(String, rules::Diagnostic)>,
    /// `(rel_path, allow)` pairs, in path order.
    allows: Vec<(String, rules::Allow)>,
    files_scanned: usize,
}

/// Collect `.rs` files under `root`, sorted for deterministic output.
fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|ext| ext == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

fn lint_tree(root: &Path) -> Result<TreeReport, String> {
    let mut report = TreeReport::default();
    for path in collect_rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .map_err(|e| format!("relativizing {}: {e}", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            std::fs::read_to_string(&path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        let file_report = rules::check_file(&rel, &src);
        report.files_scanned += 1;
        report.diagnostics.extend(file_report.diagnostics.into_iter().map(|d| (rel.clone(), d)));
        report.allows.extend(file_report.allows.into_iter().map(|a| (rel.clone(), a)));
    }
    Ok(report)
}

fn default_root() -> PathBuf {
    // xtask/ sits next to rust/ at the workspace root.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src")
}

fn print_usage() {
    eprintln!("usage: cargo xtask lint [--root <dir>]");
    eprintln!();
    eprintln!("Checks HexGen project invariants over <dir> (default: rust/src).");
    eprintln!("Rules: {}", rules::RULES.join(", "));
}

fn run_lint(root: &Path) -> ExitCode {
    let report = match lint_tree(root) {
        Ok(report) => report,
        Err(err) => {
            eprintln!("xtask lint: {err}");
            return ExitCode::from(2);
        }
    };
    for (rel, d) in &report.diagnostics {
        println!("{}/{rel}:{}: [{}] {}", root.display(), d.line, d.rule, d.msg);
    }
    for (rel, a) in &report.allows {
        if a.used {
            println!("{}/{rel}:{}: note: allow({}) in effect", root.display(), a.line, a.rule);
        }
    }
    let used_allows = report.allows.iter().filter(|(_, a)| a.used).count();
    println!(
        "lint: {} files scanned, {} diagnostics, {} allows in effect",
        report.files_scanned,
        report.diagnostics.len(),
        used_allows
    );
    if report.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut args = args.iter().map(String::as_str);
    match args.next() {
        Some("lint") => {
            let mut root = default_root();
            loop {
                match args.next() {
                    None => break,
                    Some("--root") => match args.next() {
                        Some(dir) => root = PathBuf::from(dir),
                        None => {
                            print_usage();
                            return ExitCode::from(2);
                        }
                    },
                    Some(other) => {
                        eprintln!("xtask lint: unknown argument `{other}`");
                        print_usage();
                        return ExitCode::from(2);
                    }
                }
            }
            run_lint(&root)
        }
        _ => {
            print_usage();
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: the real tree must be lint-clean, with zero
    /// `// lint: allow` entries under `rust/src/coordinator/`. Running
    /// under plain `cargo test` makes tier-1 itself enforce the
    /// invariants even where CI is unavailable.
    #[test]
    fn repository_tree_is_lint_clean() {
        let root = default_root();
        let report = lint_tree(&root).unwrap_or_else(|e| panic!("lint walk failed: {e}"));
        assert!(report.files_scanned > 10, "suspiciously few files under {}", root.display());
        let rendered: Vec<String> = report
            .diagnostics
            .iter()
            .map(|(rel, d)| format!("{rel}:{}: [{}] {}", d.line, d.rule, d.msg))
            .collect();
        assert!(rendered.is_empty(), "lint diagnostics on the tree:\n{}", rendered.join("\n"));
        let coordinator_allows: Vec<&String> = report
            .allows
            .iter()
            .filter(|(rel, _)| rel.starts_with("coordinator/"))
            .map(|(rel, _)| rel)
            .collect();
        assert!(coordinator_allows.is_empty(), "allows under coordinator/: {coordinator_allows:?}");
    }

    /// Seeding a forbidden pattern must fail with a file:line diagnostic
    /// (acceptance criterion), exercised end-to-end through the walker.
    #[test]
    fn seeded_violation_fails_through_the_walker() {
        let dir = std::env::temp_dir().join(format!("xtask-lint-seed-{}", std::process::id()));
        let coord = dir.join("coordinator");
        std::fs::create_dir_all(&coord).expect("create fixture dir");
        std::fs::write(coord.join("bad.rs"), "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n")
            .expect("write fixture");
        let report = lint_tree(&dir).expect("lint fixture tree");
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(report.diagnostics.len(), 1);
        let (rel, d) = &report.diagnostics[0];
        assert_eq!(rel, "coordinator/bad.rs");
        assert_eq!(d.rule, "serving-unwrap");
        assert_eq!(d.line, 1);
    }
}
