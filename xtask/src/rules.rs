//! The HexGen project-invariant rule set, applied to one file's token
//! stream at a time. Paths are relative to `rust/src` with forward
//! slashes (`coordinator/service.rs`).
//!
//! Rules (see `rust/README.md` § Correctness tooling for the catalog):
//!
//! * `serving-unwrap` — no `.unwrap()` / `.expect()` / `panic!` /
//!   `unreachable!` in the serving path outside `#[cfg(test)]`.
//!   `assert!` / `assert_eq!` are deliberately permitted: they state
//!   call contracts, and the worker loop's panic recovery contains
//!   them.
//! * `lock-unwrap` — no bare `.lock().unwrap()` / `.try_lock().unwrap()`
//!   anywhere, tests included: poison must be handled, not propagated.
//! * `raw-mutex` — no raw `Mutex` / `Condvar` / `RwLock` in the serving
//!   path; use `util::sync::OrderedMutex` with a declared rank.
//! * `hot-path-alloc` — no allocating constructs inside
//!   `// lint: hot-path` regions: `format!` / `vec!`, `.clone()`,
//!   `.to_string()` / `.to_owned()` / `.to_vec()`, `with_capacity`,
//!   `.collect()`, `Box::new`, `String::from`. Writing into
//!   pre-reserved buffers (`push`, `extend_from_slice`, `resize`,
//!   `copy_from_slice`, `clear`) is fine.
//! * `lock-order` — lexical shadow of the `util::sync::locks` table:
//!   within one `fn`, direct `<field>.lock()` calls on ranked fields
//!   must appear in strictly ascending rank order.
//! * `lint-marker` — the directives themselves must be well-formed:
//!   balanced hot-path markers, known rule names in `allow(...)`, and
//!   no allow that suppresses nothing.
//! * `allow-in-coordinator` — `// lint: allow` is banned outright under
//!   `coordinator/`; fix the code instead.

use crate::lexer::{self, Directive, Spanned, Tok};
use std::collections::BTreeSet;

/// Rule names accepted by `// lint: allow(<rule>)`.
pub const RULES: &[&str] =
    &["serving-unwrap", "lock-unwrap", "raw-mutex", "hot-path-alloc", "lock-order"];

/// Lexical mirror of the lock-order table in `rust/src/util/sync.rs`
/// (`util::sync::locks`). Field name → rank; keep the two in sync.
pub const LOCK_RANKS: &[(&str, u16)] = &[("speeds", 10), ("comm_rx", 20), ("comm_total", 30)];

/// Allocating calls banned inside hot-path regions when followed by `(`.
const HOT_BANNED_CALLS: &[&str] =
    &["clone", "to_string", "to_owned", "to_vec", "with_capacity", "collect"];

/// Allocating macros banned inside hot-path regions (`name!`).
const HOT_BANNED_MACROS: &[&str] = &["format", "vec"];

#[derive(Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub line: usize,
    pub msg: String,
}

/// One `// lint: allow(<rule>)` marker and whether it suppressed
/// anything.
#[derive(Debug)]
pub struct Allow {
    pub rule: String,
    pub line: usize,
    pub used: bool,
}

#[derive(Debug, Default)]
pub struct FileReport {
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<Allow>,
}

/// Files where a panic kills a live replica or handler thread rather
/// than a CLI invocation.
fn is_serving_path(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel == "runtime/engine.rs" || rel == "runtime/backend.rs"
}

fn ident_at<'a>(toks: &'a [Spanned], i: usize) -> Option<&'a str> {
    match toks.get(i)?.tok {
        Tok::Ident(ref name) => Some(name),
        Tok::Punct(_) => None,
    }
}

fn punct_at(toks: &[Spanned], i: usize, c: char) -> bool {
    matches!(toks.get(i), Some(Spanned { tok: Tok::Punct(p), .. }) if *p == c)
}

/// Parse an attribute starting at the `[` token index; returns the
/// identifiers inside it and the token index just past the closing `]`.
fn parse_attr(toks: &[Spanned], open: usize) -> (Vec<String>, usize) {
    let mut idents = Vec::new();
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match &toks[i].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return (idents, i + 1);
                }
            }
            Tok::Ident(name) => idents.push(name.clone()),
            Tok::Punct(_) => {}
        }
        i += 1;
    }
    (idents, toks.len())
}

/// Does this attribute gate its item to test builds? `#[test]`,
/// `#[cfg(test)]`, and `#[cfg(all(test, ...))]` do; `#[cfg(not(test))]`
/// emphatically does not.
fn is_test_gate(idents: &[String]) -> bool {
    match idents.first().map(String::as_str) {
        Some("test") => true,
        Some("cfg") => idents.iter().any(|s| s == "test") && !idents.iter().any(|s| s == "not"),
        _ => false,
    }
}

/// Skip one item starting at `i` (past the gating attribute): consume
/// any further attributes, then either a `;`-terminated item or a
/// braced body. Returns the token index just past the item.
fn skip_item(toks: &[Spanned], mut i: usize) -> usize {
    while punct_at(toks, i, '#') && punct_at(toks, i + 1, '[') {
        let (_, after) = parse_attr(toks, i + 1);
        i = after;
    }
    let mut paren = 0i32;
    let mut bracket = 0i32;
    let mut brace = 0i32;
    let mut seen_brace = false;
    while i < toks.len() {
        match toks[i].tok {
            Tok::Punct('(') => paren += 1,
            Tok::Punct(')') => paren -= 1,
            Tok::Punct('[') => bracket += 1,
            Tok::Punct(']') => bracket -= 1,
            Tok::Punct('{') => {
                brace += 1;
                seen_brace = true;
            }
            Tok::Punct('}') => {
                brace -= 1;
                if seen_brace && brace == 0 {
                    return i + 1;
                }
            }
            Tok::Punct(';') if !seen_brace && paren == 0 && bracket == 0 => return i + 1,
            _ => {}
        }
        i += 1;
    }
    toks.len()
}

/// Token-index ranges covered by test-gated items.
fn test_token_ranges(toks: &[Spanned]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if punct_at(toks, i, '#') && punct_at(toks, i + 1, '[') {
            let (idents, after) = parse_attr(toks, i + 1);
            if is_test_gate(&idents) {
                let end = skip_item(toks, after);
                ranges.push((i, end));
                i = end;
            } else {
                i = after;
            }
        } else {
            i += 1;
        }
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], i: usize) -> bool {
    ranges.iter().any(|&(s, e)| i >= s && i < e)
}

/// Bookkeeping for one allow marker while matching diagnostics.
struct AllowEntry {
    rule: String,
    marker_line: usize,
    /// The code line the marker covers: its own line, or — when the
    /// marker sits on a line of its own — the next line holding code.
    target_line: usize,
    used: bool,
}

/// Run every rule over one file.
pub fn check_file(rel_path: &str, src: &str) -> FileReport {
    let scan = lexer::scan(src);
    let toks = &scan.toks;
    let serving = is_serving_path(rel_path);
    let in_coordinator = rel_path.starts_with("coordinator/");
    let test_ranges = test_token_ranges(toks);
    let token_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();

    let mut diags: Vec<Diagnostic> = Vec::new();

    // --- Marker validation: balanced hot-path regions, known allow rules.
    let mut hot_regions: Vec<(usize, usize)> = Vec::new(); // (start line, end line]
    let mut open_at: Option<usize> = None;
    let mut allow_entries: Vec<AllowEntry> = Vec::new();
    for m in &scan.markers {
        match &m.directive {
            Directive::HotPathStart => {
                if let Some(start) = open_at {
                    diags.push(Diagnostic {
                        rule: "lint-marker",
                        line: m.line,
                        msg: format!("hot-path region opened here while one from line {start} is still open"),
                    });
                } else {
                    open_at = Some(m.line);
                }
            }
            Directive::HotPathEnd => match open_at.take() {
                Some(start) => hot_regions.push((start, m.line)),
                None => diags.push(Diagnostic {
                    rule: "lint-marker",
                    line: m.line,
                    msg: "hot-path-end without a matching hot-path marker".to_string(),
                }),
            },
            Directive::Allow(rule) => {
                if !RULES.contains(&rule.as_str()) {
                    diags.push(Diagnostic {
                        rule: "lint-marker",
                        line: m.line,
                        msg: format!("allow({rule}) names an unknown rule; known: {}", RULES.join(", ")),
                    });
                    continue;
                }
                let target_line = if token_lines.contains(&m.line) {
                    m.line
                } else {
                    token_lines.range(m.line + 1..).next().copied().unwrap_or(m.line)
                };
                allow_entries.push(AllowEntry {
                    rule: rule.clone(),
                    marker_line: m.line,
                    target_line,
                    used: false,
                });
            }
        }
    }
    if let Some(start) = open_at {
        diags.push(Diagnostic {
            rule: "lint-marker",
            line: start,
            msg: "hot-path region is never closed (missing `// lint: hot-path-end`)".to_string(),
        });
        hot_regions.push((start, usize::MAX));
    }
    let in_hot = |line: usize| hot_regions.iter().any(|&(s, e)| line > s && line <= e);

    // --- Token-stream rules.
    let mut raw: Vec<Diagnostic> = Vec::new();
    // Highest lock rank acquired so far in the current fn (lock-order).
    let mut max_rank: Option<(u16, &'static str, usize)> = None;
    for i in 0..toks.len() {
        let line = toks[i].line;
        let name = match ident_at(toks, i) {
            Some(name) => name,
            None => continue,
        };
        let in_test = in_ranges(&test_ranges, i);

        if name == "fn" {
            max_rank = None;
        }

        // lock-unwrap: `.lock().unwrap()` / `.try_lock().expect(...)`,
        // everywhere, tests included.
        if (name == "unwrap" || name == "expect")
            && punct_at(toks, i.wrapping_sub(1), '.')
            && punct_at(toks, i + 1, '(')
        {
            let on_lock = i >= 4
                && punct_at(toks, i - 2, ')')
                && punct_at(toks, i - 3, '(')
                && matches!(ident_at(toks, i - 4), Some("lock" | "try_lock"));
            if on_lock {
                raw.push(Diagnostic {
                    rule: "lock-unwrap",
                    line,
                    msg: format!(
                        ".lock().{name}() propagates mutex poison; use util::sync::OrderedMutex \
                         or handle PoisonError"
                    ),
                });
            } else if serving && !in_test {
                raw.push(Diagnostic {
                    rule: "serving-unwrap",
                    line,
                    msg: format!(
                        ".{name}() in the serving path can kill a replica thread; return a typed \
                         error or recover"
                    ),
                });
            }
        }

        // serving-unwrap: panicking macros in the serving path.
        if serving && !in_test && (name == "panic" || name == "unreachable") && punct_at(toks, i + 1, '!')
        {
            raw.push(Diagnostic {
                rule: "serving-unwrap",
                line,
                msg: format!(
                    "{name}! in the serving path kills a replica thread and poisons shared locks; \
                     return a typed error instead"
                ),
            });
        }

        // raw-mutex: unranked lock types in the serving path.
        if serving && !in_test && matches!(name, "Mutex" | "Condvar" | "RwLock") {
            raw.push(Diagnostic {
                rule: "raw-mutex",
                line,
                msg: format!(
                    "raw {name} in the serving path; use util::sync::OrderedMutex/OrderedCondvar \
                     with a rank from util::sync::locks"
                ),
            });
        }

        // hot-path-alloc: allocation inside a marked region.
        if in_hot(line) {
            if HOT_BANNED_CALLS.contains(&name) && punct_at(toks, i + 1, '(') {
                raw.push(Diagnostic {
                    rule: "hot-path-alloc",
                    line,
                    msg: format!("{name}() allocates inside a `lint: hot-path` region"),
                });
            }
            if HOT_BANNED_MACROS.contains(&name) && punct_at(toks, i + 1, '!') {
                raw.push(Diagnostic {
                    rule: "hot-path-alloc",
                    line,
                    msg: format!("{name}! allocates inside a `lint: hot-path` region"),
                });
            }
            let static_ctor = (name == "Box" || name == "String")
                && punct_at(toks, i + 1, ':')
                && punct_at(toks, i + 2, ':')
                && matches!(ident_at(toks, i + 3), Some("new" | "from"));
            if static_ctor {
                raw.push(Diagnostic {
                    rule: "hot-path-alloc",
                    line,
                    msg: format!("{name}::… constructor allocates inside a `lint: hot-path` region"),
                });
            }
        }

        // lock-order: direct `<ranked field>.lock()` calls must ascend
        // within one fn. Lexical approximation of the runtime check in
        // util::sync (which is exact but debug-only).
        if !in_test
            && punct_at(toks, i + 1, '.')
            && matches!(ident_at(toks, i + 2), Some("lock" | "try_lock"))
            && punct_at(toks, i + 3, '(')
        {
            if let Some(&(field, rank)) = LOCK_RANKS.iter().find(|&&(f, _)| f == name) {
                match max_rank {
                    Some((held, held_field, held_line)) if rank <= held => {
                        raw.push(Diagnostic {
                            rule: "lock-order",
                            line,
                            msg: format!(
                                "{field}.lock() (rank {rank}) after {held_field}.lock() (rank \
                                 {held}, line {held_line}) in the same fn; acquire in ascending \
                                 rank order (see util::sync::locks)"
                            ),
                        });
                    }
                    _ => max_rank = Some((rank, field, line)),
                }
            }
        }
    }

    // --- Allow filtering: a marker suppresses same-rule diagnostics on
    // its target line.
    let mut kept: Vec<Diagnostic> = Vec::new();
    for d in raw {
        let slot = allow_entries
            .iter_mut()
            .find(|a| a.rule == d.rule && a.target_line == d.line);
        match slot {
            Some(a) => a.used = true,
            None => kept.push(d),
        }
    }
    diags.extend(kept);

    for a in &allow_entries {
        if !a.used {
            diags.push(Diagnostic {
                rule: "lint-marker",
                line: a.marker_line,
                msg: format!("allow({}) suppresses nothing on line {}; remove it", a.rule, a.target_line),
            });
        }
        if in_coordinator {
            diags.push(Diagnostic {
                rule: "allow-in-coordinator",
                line: a.marker_line,
                msg: format!(
                    "allow({}) is banned under coordinator/; fix the violation instead",
                    a.rule
                ),
            });
        }
    }

    diags.sort_by_key(|d| d.line);
    FileReport {
        diagnostics: diags,
        allows: allow_entries
            .into_iter()
            .map(|a| Allow { rule: a.rule, line: a.marker_line, used: a.used })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(report: &FileReport) -> Vec<&'static str> {
        report.diagnostics.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn unwrap_in_serving_path_is_flagged_with_line() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let report = check_file("coordinator/service.rs", src);
        assert_eq!(rules_fired(&report), vec!["serving-unwrap"]);
        assert_eq!(report.diagnostics[0].line, 2);
    }

    #[test]
    fn expect_and_panicking_macros_are_flagged() {
        let src = "fn f() {\n    y.expect(\"msg\");\n    panic!(\"boom\");\n    unreachable!()\n}\n";
        let report = check_file("runtime/engine.rs", src);
        assert_eq!(rules_fired(&report), vec!["serving-unwrap"; 3]);
    }

    #[test]
    fn non_serving_files_may_unwrap() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(check_file("planner/cost.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn asserts_are_permitted_in_serving_path() {
        let src = "fn f(n: usize) {\n    assert!(n > 0);\n    assert_eq!(n % 2, 0);\n}\n";
        assert!(check_file("coordinator/collective.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); panic!(\"in test\"); }\n}\n";
        assert!(check_file("coordinator/api.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn test_fn_outside_mod_is_exempt_but_neighbors_are_not() {
        let src = "#[test]\nfn t() { x.unwrap(); }\nfn live() { y.unwrap(); }\n";
        let report = check_file("coordinator/api.rs", src);
        assert_eq!(rules_fired(&report), vec!["serving-unwrap"]);
        assert_eq!(report.diagnostics[0].line, 3);
    }

    #[test]
    fn cfg_not_test_is_not_exempt() {
        let src = "#[cfg(not(test))]\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_fired(&check_file("coordinator/api.rs", src)), vec!["serving-unwrap"]);
    }

    #[test]
    fn lock_unwrap_is_flagged_everywhere_even_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let g = m.lock().unwrap(); }\n}\n";
        let report = check_file("util/stats.rs", src);
        assert_eq!(rules_fired(&report), vec!["lock-unwrap"]);
        assert_eq!(report.diagnostics[0].line, 3);
    }

    #[test]
    fn try_lock_expect_is_flagged() {
        let src = "fn f() { let g = m.try_lock().expect(\"lock\"); }\n";
        assert_eq!(rules_fired(&check_file("planner/cost.rs", src)), vec!["lock-unwrap"]);
    }

    #[test]
    fn raw_mutex_in_coordinator_is_flagged() {
        let src = "use std::sync::Mutex;\nstruct S { m: Mutex<u32> }\n";
        let report = check_file("coordinator/router.rs", src);
        assert_eq!(rules_fired(&report), vec!["raw-mutex", "raw-mutex"]);
    }

    #[test]
    fn ordered_mutex_is_not_raw() {
        let src = "use crate::util::sync::{OrderedMutex, OrderedCondvar, OrderedMutexGuard};\n\
                   struct S { m: OrderedMutex<u32> }\n";
        assert!(check_file("coordinator/router.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn hot_path_allocations_are_flagged() {
        let src = "fn step(&mut self) {\n    // lint: hot-path\n    let a = x.clone();\n    let b = format!(\"{a}\");\n    let c: Vec<u32> = ys.iter().collect();\n    let d = Vec::with_capacity(8);\n    let e = Box::new(3);\n    // lint: hot-path-end\n    let after = z.to_string();\n}\n";
        let report = check_file("coordinator/pipeline.rs", src);
        assert_eq!(rules_fired(&report), vec!["hot-path-alloc"; 5]);
        let lines: Vec<usize> = report.diagnostics.iter().map(|d| d.line).collect();
        assert_eq!(lines, vec![3, 4, 5, 6, 7]); // `after` is outside the region
    }

    #[test]
    fn hot_path_writes_into_reserved_buffers_are_fine() {
        let src = "fn step(&mut self) {\n    // lint: hot-path\n    buf.clear();\n    buf.push(1);\n    buf.extend_from_slice(&xs);\n    dst.copy_from_slice(&src);\n    // lint: hot-path-end\n}\n";
        assert!(check_file("coordinator/pipeline.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn unbalanced_markers_are_diagnosed() {
        let end_only = "fn f() {}\n// lint: hot-path-end\n";
        assert_eq!(rules_fired(&check_file("runtime/reference.rs", end_only)), vec!["lint-marker"]);
        let unclosed = "// lint: hot-path\nfn f() {}\n";
        assert_eq!(rules_fired(&check_file("runtime/reference.rs", unclosed)), vec!["lint-marker"]);
    }

    #[test]
    fn allow_suppresses_and_is_counted() {
        let src = "fn f() {\n    x.unwrap() // lint: allow(serving-unwrap) startup-only path\n}\n";
        let report = check_file("runtime/engine.rs", src);
        assert!(report.diagnostics.is_empty());
        assert_eq!(report.allows.len(), 1);
        assert!(report.allows[0].used);
        assert_eq!(report.allows[0].rule, "serving-unwrap");
    }

    #[test]
    fn allow_on_its_own_line_covers_the_next_code_line() {
        let src = "fn f() {\n    // lint: allow(serving-unwrap) wrapped by rustfmt\n    x.unwrap()\n}\n";
        assert!(check_file("runtime/engine.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn unused_allow_is_a_diagnostic() {
        let src = "fn f() {\n    // lint: allow(serving-unwrap)\n    let y = 1;\n}\n";
        assert_eq!(rules_fired(&check_file("runtime/engine.rs", src)), vec!["lint-marker"]);
    }

    #[test]
    fn unknown_allow_rule_is_a_diagnostic() {
        let src = "// lint: allow(no-such-rule)\nfn f() {}\n";
        assert_eq!(rules_fired(&check_file("runtime/engine.rs", src)), vec!["lint-marker"]);
    }

    #[test]
    fn allow_in_coordinator_is_itself_a_violation() {
        let src = "fn f() {\n    x.unwrap() // lint: allow(serving-unwrap)\n}\n";
        let report = check_file("coordinator/service.rs", src);
        assert_eq!(rules_fired(&report), vec!["allow-in-coordinator"]);
    }

    #[test]
    fn lock_order_inversion_is_flagged() {
        let src = "fn comm_stats(&self) {\n    let total = self.comm_total.lock();\n    let rx = self.comm_rx.lock();\n}\n";
        let report = check_file("coordinator/service.rs", src);
        assert_eq!(rules_fired(&report), vec!["lock-order"]);
        assert_eq!(report.diagnostics[0].line, 3);
    }

    #[test]
    fn ascending_lock_order_is_fine_and_resets_per_fn() {
        let src = "fn a(&self) {\n    let rx = self.comm_rx.lock();\n    let total = self.comm_total.lock();\n}\nfn b(&self) {\n    let s = self.speeds.lock();\n}\n";
        assert!(check_file("coordinator/service.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire_rules() {
        let src = "fn f() {\n    // x.unwrap() would panic here\n    let s = \"panic! .lock().unwrap()\";\n}\n";
        assert!(check_file("coordinator/service.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn clean_realistic_snippet_is_silent() {
        let src = "use crate::util::sync::{locks, OrderedMutex};\n\
                   pub struct Router {\n    speeds: OrderedMutex<SpeedState>,\n}\n\
                   impl Router {\n    fn state(&self) -> OrderedMutexGuard<'_, SpeedState> {\n        self.speeds.lock()\n    }\n}\n";
        let report = check_file("coordinator/router.rs", src);
        assert!(report.diagnostics.is_empty(), "unexpected: {:?}", report.diagnostics);
        assert!(report.allows.is_empty());
    }
}
