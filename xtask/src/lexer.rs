//! A hand-rolled Rust token scanner: just enough lexing for lexical
//! lint rules. Comments and string/char literals are stripped (so
//! `// calls unwrap()` or `"panic!"` never trip a rule), `lint:`
//! directives inside line comments are surfaced as [`Marker`]s, and
//! everything else is reduced to identifiers and single-character
//! punctuation with 1-based line numbers.
//!
//! Deliberately NOT a full lexer: numbers, lifetimes, and operators are
//! consumed or split without semantic meaning. The rules only ever
//! match identifier/punctuation sequences (`lock ( ) . unwrap`,
//! `vec !`, `Box :: new`), which this faithfully preserves.

/// A significant token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Punct(char),
}

/// A token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Spanned {
    pub tok: Tok,
    pub line: usize,
}

/// A `// lint: ...` directive found in a line comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Directive {
    /// `// lint: hot-path` — opens an allocation-free region.
    HotPathStart,
    /// `// lint: hot-path-end` — closes it.
    HotPathEnd,
    /// `// lint: allow(<rule>)` — suppresses `<rule>` on this line.
    Allow(String),
}

#[derive(Debug, Clone)]
pub struct Marker {
    pub directive: Directive,
    pub line: usize,
}

/// Scan result: token stream plus lint directives.
pub struct Scan {
    pub toks: Vec<Spanned>,
    pub markers: Vec<Marker>,
}

/// Parse the text of a line comment into a lint directive, if any.
/// Trailing prose after the directive is allowed and ignored.
fn parse_directive(comment: &str) -> Option<Directive> {
    let rest = comment.trim().strip_prefix("lint:")?.trim_start();
    if rest.starts_with("hot-path-end") {
        Some(Directive::HotPathEnd)
    } else if rest.starts_with("hot-path") {
        Some(Directive::HotPathStart)
    } else if let Some(inner) = rest.strip_prefix("allow(") {
        let rule = inner.split(')').next()?.trim();
        if rule.is_empty() {
            None
        } else {
            Some(Directive::Allow(rule.to_string()))
        }
    } else {
        None
    }
}

/// Raw-string opening at `b[i]` (`r"`, `r#"`, `br##"` …): returns
/// `(index of the opening quote, number of hashes)`.
fn raw_string_open(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        Some((j, hashes))
    } else {
        None
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unrecognized bytes become punctuation.
pub fn scan(src: &str) -> Scan {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut markers = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            '/' if b.get(i + 1) == Some(&'/') => {
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != '\n' {
                    j += 1;
                }
                let text: String = b[start..j].iter().collect();
                if let Some(directive) = parse_directive(&text) {
                    markers.push(Marker { directive, line });
                }
                i = j; // the newline arm advances `line`
            }
            '/' if b.get(i + 1) == Some(&'*') => {
                // Block comments nest in Rust.
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == '\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
            }
            '"' => {
                i += 1;
                while i < b.len() {
                    match b[i] {
                        '\\' => i += 2,
                        '"' => {
                            i += 1;
                            break;
                        }
                        ch => {
                            if ch == '\n' {
                                line += 1;
                            }
                            i += 1;
                        }
                    }
                }
            }
            'r' | 'b' if raw_string_open(&b, i).is_some() => {
                let (quote, hashes) = match raw_string_open(&b, i) {
                    Some(open) => open,
                    None => unreachable!("guard checked"),
                };
                i = quote + 1;
                'body: while i < b.len() {
                    if b[i] == '"' {
                        let mut k = 0;
                        while k < hashes && b.get(i + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            i += 1 + hashes;
                            break 'body;
                        }
                    }
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            'r' if b.get(i + 1) == Some(&'#') && b.get(i + 2).copied().is_some_and(is_ident_char) =>
            {
                // Raw identifier (`r#fn`): drop the `r#`, lex the name.
                i += 2;
            }
            '\'' => {
                if b.get(i + 1) == Some(&'\\') {
                    // Escaped char literal: the closing quote is the
                    // first `'` at or after i+3 (`'\''` closes at i+3).
                    let mut j = i + 3;
                    while j < b.len() && b[j] != '\'' {
                        if b[j] == '\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                    i = j + 1;
                } else if b.get(i + 2) == Some(&'\'') {
                    i += 3; // plain char literal, e.g. 'a'
                } else {
                    i += 1; // lifetime or loop label: name lexes as ident
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && is_ident_char(b[i]) {
                    i += 1;
                }
                toks.push(Spanned { tok: Tok::Ident(b[start..i].iter().collect()), line });
            }
            c if c.is_ascii_digit() => {
                // Numbers with suffixes (`0f32`, `1_000`, `0x1F`); a `.`
                // is part of the number only when a digit follows, so
                // `1.to_string()` and `0..n` still tokenize the methods.
                i += 1;
                while i < b.len() {
                    let ch = b[i];
                    if ch.is_ascii_alphanumeric() || ch == '_' {
                        i += 1;
                    } else if ch == '.' && b.get(i + 1).is_some_and(char::is_ascii_digit) {
                        i += 2;
                    } else {
                        break;
                    }
                }
            }
            c if c.is_whitespace() => i += 1,
            c => {
                toks.push(Spanned { tok: Tok::Punct(c), line });
                i += 1;
            }
        }
    }
    Scan { toks, markers }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        scan(src)
            .toks
            .into_iter()
            .filter_map(|s| match s.tok {
                Tok::Ident(name) => Some(name),
                Tok::Punct(_) => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        let src = r##"
            // this unwrap() is prose
            /* and this panic! too /* nested */ still comment */
            let s = "panic! inside a string";
            let r = r#"raw with "quote" and unwrap()"#;
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(!ids.contains(&"panic".to_string()));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let ids = idents("fn f<'a>(x: &'a str) -> char { let c = '\\''; let d = 'x'; ' ' }");
        assert!(ids.contains(&"a".to_string())); // lifetime name lexes as ident
        assert!(ids.contains(&"str".to_string()));
        assert!(ids.contains(&"d".to_string())); // code after the literals still lexes
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        let ids = idents("let x = 1.to_string(); let y = 3.14f32; for i in 0..n {}");
        assert!(ids.contains(&"to_string".to_string()));
        assert!(ids.contains(&"n".to_string()));
    }

    #[test]
    fn directives_parse_with_trailing_prose() {
        let src = "// lint: hot-path — steady state allocates nothing\nx();\n// lint: hot-path-end\n// lint: allow(serving-unwrap) justified because reasons\n";
        let markers = scan(src).markers;
        assert_eq!(markers.len(), 3);
        assert_eq!(markers[0].directive, Directive::HotPathStart);
        assert_eq!(markers[0].line, 1);
        assert_eq!(markers[1].directive, Directive::HotPathEnd);
        assert_eq!(markers[2].directive, Directive::Allow("serving-unwrap".to_string()));
        assert_eq!(markers[2].line, 4);
    }

    #[test]
    fn doc_comments_do_not_parse_directives() {
        assert!(scan("/// lint: hot-path\n").markers.is_empty());
    }

    #[test]
    fn line_numbers_track_multiline_constructs() {
        let src = "/* a\nb */\nlet x = \"s\ntring\";\ntarget();\n";
        let toks = scan(src).toks;
        let target = toks
            .iter()
            .find(|t| t.tok == Tok::Ident("target".to_string()))
            .expect("target token");
        assert_eq!(target.line, 5);
    }
}
