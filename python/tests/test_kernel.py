"""Pallas kernel correctness: flash_attention / flash_decode vs ref.py.

Hypothesis sweeps shapes and dtypes; fixed cases pin the serving shapes
the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, flash_decode
from compile.kernels.ref import attention_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * scale


TOL = dict(rtol=2e-5, atol=2e-5)


class TestPrefillFixed:
    def test_serving_shape(self):
        # the exact prefill shape the artifacts use
        q = rand(0, (4, 4, 32, 32))
        k = rand(1, (4, 4, 32, 32))
        v = rand(2, (4, 4, 32, 32))
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, attention_ref(q, k, v, True), **TOL)

    def test_non_causal(self):
        q = rand(3, (2, 2, 32, 16))
        k = rand(4, (2, 2, 64, 16))
        v = rand(5, (2, 2, 64, 16))
        out = flash_attention(q, k, v, causal=False)
        np.testing.assert_allclose(out, attention_ref(q, k, v, False), **TOL)

    def test_single_head_single_batch(self):
        q = rand(6, (1, 1, 16, 8))
        k = rand(7, (1, 1, 16, 8))
        v = rand(8, (1, 1, 16, 8))
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, attention_ref(q, k, v, True), **TOL)

    def test_block_sizes_equivalent(self):
        q = rand(9, (2, 2, 64, 32))
        k = rand(10, (2, 2, 64, 32))
        v = rand(11, (2, 2, 64, 32))
        ref = attention_ref(q, k, v, True)
        for bq, bk in [(16, 16), (32, 16), (16, 32), (64, 64), (8, 8)]:
            out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            np.testing.assert_allclose(out, ref, **TOL)

    def test_large_magnitude_stability(self):
        # online softmax must survive large score magnitudes
        q = rand(12, (1, 2, 32, 32), scale=30.0)
        k = rand(13, (1, 2, 32, 32), scale=30.0)
        v = rand(14, (1, 2, 32, 32))
        out = flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, True)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_under_jit(self):
        q = rand(15, (2, 4, 32, 32))
        k = rand(16, (2, 4, 32, 32))
        v = rand(17, (2, 4, 32, 32))
        out = jax.jit(lambda a, b, c: flash_attention(a, b, c, causal=True))(q, k, v)
        np.testing.assert_allclose(out, attention_ref(q, k, v, True), **TOL)


class TestDecodeFixed:
    def test_serving_shape(self):
        kc = rand(20, (4, 4, 64, 32))
        vc = rand(21, (4, 4, 64, 32))
        q = rand(22, (4, 4, 1, 32))
        for length in (1, 16, 33, 64):
            out = flash_decode(q, kc, vc, length)
            ref = decode_attention_ref(q, kc, vc, length)
            np.testing.assert_allclose(out, ref, **TOL, err_msg=f"len={length}")

    def test_garbage_beyond_length_ignored(self):
        kc = rand(23, (1, 2, 32, 16))
        vc = rand(24, (1, 2, 32, 16))
        q = rand(25, (1, 2, 1, 16))
        out1 = flash_decode(q, kc, vc, 10)
        # poison the tail — result must be identical
        kc2 = kc.at[:, :, 10:, :].set(1e6)
        vc2 = vc.at[:, :, 10:, :].set(-1e6)
        out2 = flash_decode(q, kc2, vc2, 10)
        np.testing.assert_allclose(out1, out2, rtol=0, atol=0)

    def test_traced_length(self):
        kc = rand(26, (2, 2, 32, 16))
        vc = rand(27, (2, 2, 32, 16))
        q = rand(28, (2, 2, 1, 16))
        f = jax.jit(lambda q, k, v, n: flash_decode(q, k, v, n))
        for n in (1, 7, 32):
            np.testing.assert_allclose(
                f(q, kc, vc, jnp.int32(n)),
                decode_attention_ref(q, kc, vc, n),
                **TOL,
            )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    nh=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_hypothesis(b, nh, s_blocks, dh, causal, seed):
    s = 16 * s_blocks
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, nh, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, nh, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, nh, s, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, attention_ref(q, k, v, causal), **TOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    nh=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 32]),
    data=st.data(),
)
def test_decode_hypothesis(b, nh, s_blocks, dh, data):
    s_max = 16 * s_blocks
    length = data.draw(st.integers(1, s_max))
    seed = data.draw(st.integers(0, 2**31 - 1))
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, nh, 1, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (b, nh, s_max, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (b, nh, s_max, dh), jnp.float32)
    out = flash_decode(q, kc, vc, length)
    np.testing.assert_allclose(out, decode_attention_ref(q, kc, vc, length), **TOL)


class TestShapeValidation:
    def test_misaligned_seq_rejected(self):
        q = rand(30, (1, 1, 20, 8))
        with pytest.raises(AssertionError):
            flash_attention(q, q, q, causal=True)

    def test_causal_requires_square(self):
        q = rand(31, (1, 1, 16, 8))
        k = rand(32, (1, 1, 32, 8))
        with pytest.raises(AssertionError):
            flash_attention(q, k, k, causal=True)
