"""Layer-2 model tests: TP shard algebra, prefill/decode consistency, and
the stage-composition oracle the Rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.model import CFG

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=5e-4, atol=5e-4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(0)


def rand_x(seed, b, s=CFG.prompt_len):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (b, s, CFG.hidden), jnp.float32)


class TestSharding:
    @pytest.mark.parametrize("tp", [2, 4])
    @pytest.mark.parametrize("layer", [0, 3, 5])
    def test_attn_shard_sum_equals_full(self, params, tp, layer):
        x = rand_x(1, 2)
        a1, _ = M.shard_layer(params, layer, 1, 0)
        full, _, _ = M.attn_prefill_partial(x, *a1, tp=1)
        parts = []
        for r in range(tp):
            aw, _ = M.shard_layer(params, layer, tp, r)
            p, _, _ = M.attn_prefill_partial(x, *aw, tp=tp)
            parts.append(p)
        np.testing.assert_allclose(sum(parts), full, **TOL)

    @pytest.mark.parametrize("tp", [2, 4])
    def test_mlp_shard_sum_equals_full(self, params, tp):
        x = rand_x(2, 2)
        _, m1 = M.shard_layer(params, 0, 1, 0)
        full = M.mlp_partial(x, *m1)
        parts = [
            M.mlp_partial(x, *M.shard_layer(params, 0, tp, r)[1])
            for r in range(tp)
        ]
        np.testing.assert_allclose(sum(parts), full, **TOL)

    def test_shard_shapes(self, params):
        for tp in CFG.tp_degrees:
            (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(
                params, 0, tp, 0)
            h = CFG.hidden
            assert wq.shape == (h, h // tp)
            assert wo.shape == (h // tp, h)
            assert w1.shape == (h, CFG.ffn // tp)
            assert w2.shape == (CFG.ffn // tp, h)

    def test_shards_cover_weights(self, params):
        # concatenating shard columns reconstructs the full matrix
        wq = params["layers.2.wq"]
        for tp in (2, 4):
            cols = [M.shard_layer(params, 2, tp, r)[0][1] for r in range(tp)]
            np.testing.assert_array_equal(jnp.concatenate(cols, axis=1), wq)

    def test_bad_tp_rejected(self, params):
        with pytest.raises(AssertionError):
            M.shard_layer(params, 0, 3, 0)


class TestDecodeConsistency:
    def test_decode_matches_prefill_extension(self, params):
        """Prefill over S+1 tokens == prefill over S + one decode step."""
        b = 1
        key = jax.random.PRNGKey(3)
        tokens = jax.random.randint(
            key, (b, CFG.prompt_len), 0, CFG.vocab, jnp.int32)
        logits_p, kc, vc = M.forward_prefill_full(tokens, params)
        next_tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)[:, None]

        logits_d, kc2, vc2 = M.forward_decode_full(
            next_tok, kc, vc, jnp.int32(CFG.prompt_len), params)

        # oracle: re-run prefill over prompt+next with a causal window of
        # prompt_len+1 — compare last-position logits.
        ext = jnp.concatenate([tokens, next_tok], axis=1)
        # pad to a block multiple (prompt_len+16)
        pad = 15
        ext_p = jnp.pad(ext, ((0, 0), (0, pad)))
        x = M.embed(ext_p, params["embed"])
        for i in range(CFG.layers):
            (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(
                params, i, 1, 0)
            part, _, _ = M.attn_prefill_partial(x, ln1, wq, wk, wv, wo, tp=1)
            x = x + part
            x = x + M.mlp_partial(x, ln2, w1, w2)
        from compile.kernels.ref import rmsnorm_ref
        xl = rmsnorm_ref(x[:, CFG.prompt_len, :], params["final_ln"])
        ref_logits = xl @ params["lm_head"]
        np.testing.assert_allclose(logits_d, ref_logits, **TOL)
        # caches advanced by exactly one position
        np.testing.assert_array_equal(
            kc2[:, :, :, : CFG.prompt_len, :], kc[:, :, :, : CFG.prompt_len, :])

    def test_multi_step_decode_runs(self, params):
        b = 2
        tokens = jax.random.randint(
            jax.random.PRNGKey(4), (b, CFG.prompt_len), 0, CFG.vocab, jnp.int32)
        logits, kc, vc = M.forward_prefill_full(tokens, params)
        pos = CFG.prompt_len
        for step in range(4):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, kc, vc = M.forward_decode_full(
                tok, kc, vc, jnp.int32(pos + step), params)
            assert logits.shape == (b, CFG.vocab)
            assert np.isfinite(np.asarray(logits)).all()


class TestStageComposition:
    def test_stagewise_equals_full(self, params):
        """Composing per-stage partials with host-side all-reduce+residual
        reproduces the fused full model — the contract the Rust
        coordinator depends on."""
        b = 1
        tokens = jax.random.randint(
            jax.random.PRNGKey(5), (b, CFG.prompt_len), 0, CFG.vocab, jnp.int32)
        full_logits, full_kc, _ = M.forward_prefill_full(tokens, params)

        # Asymmetric plan: stage0 = layers 0-3 at TP2; stage1 = 4-5 at TP1.
        x = M.embed(tokens, params["embed"])
        for i in range(4):
            parts = []
            for r in range(2):
                aw, _ = M.shard_layer(params, i, 2, r)
                p, _, _ = M.attn_prefill_partial(x, *aw, tp=2)
                parts.append(p)
            x = x + sum(parts)  # host all-reduce + residual
            mparts = [
                M.mlp_partial(x, *M.shard_layer(params, i, 2, r)[1])
                for r in range(2)
            ]
            x = x + sum(mparts)
        for i in range(4, 6):
            aw, mw = M.shard_layer(params, i, 1, 0)
            p, _, _ = M.attn_prefill_partial(x, *aw, tp=1)
            x = x + p
            x = x + M.mlp_partial(x, *mw)
        logits = M.lm_head_last(x, params["final_ln"], params["lm_head"])
        np.testing.assert_allclose(logits, full_logits, **TOL)

    def test_embed_lookup(self, params):
        tokens = jnp.array([[0, 1, 255]], jnp.int32)
        x = M.embed(tokens, params["embed"])
        np.testing.assert_array_equal(x[0, 0], params["embed"][0])
        np.testing.assert_array_equal(x[0, 2], params["embed"][255])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), tp=st.sampled_from([2, 4]),
       layer=st.integers(0, CFG.layers - 1))
def test_decode_shard_sum_hypothesis(seed, tp, layer):
    """Decode-phase shard-partial sums also reconstruct the full layer."""
    params = M.init_params(0)
    key = jax.random.PRNGKey(seed)
    b = 1
    x = jax.random.normal(key, (b, 1, CFG.hidden), jnp.float32)
    pos = int(jax.random.randint(key, (), CFG.prompt_len, CFG.max_seq - 1))

    def caches(nh):
        kc = jax.random.normal(
            jax.random.fold_in(key, 1), (b, nh, CFG.max_seq, CFG.head_dim))
        vc = jax.random.normal(
            jax.random.fold_in(key, 2), (b, nh, CFG.max_seq, CFG.head_dim))
        return kc, vc

    kc_full, vc_full = caches(CFG.heads)
    a1, _ = M.shard_layer(params, layer, 1, 0)
    full, _, _ = M.attn_decode_partial(x, kc_full, vc_full, pos, *a1, tp=1)

    nh_s = CFG.heads // tp
    parts = []
    for r in range(tp):
        aw, _ = M.shard_layer(params, layer, tp, r)
        kc_s = kc_full[:, r * nh_s:(r + 1) * nh_s]
        vc_s = vc_full[:, r * nh_s:(r + 1) * nh_s]
        p, _, _ = M.attn_decode_partial(x, kc_s, vc_s, pos, *aw, tp=tp)
        parts.append(p)
    np.testing.assert_allclose(sum(parts), full, **TOL)
