"""AOT pipeline tests: weights.bin format round-trip, manifest coverage,
and HLO-text production for representative artifacts."""

import json
import os
import struct
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.model import CFG

jax.config.update("jax_platform_name", "cpu")


def read_weights(path):
    """Reference reader for the HXGW format (mirrors weights.rs)."""
    out = {}
    with open(path, "rb") as fh:
        assert fh.read(4) == b"HXGW"
        version, count = struct.unpack("<II", fh.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", fh.read(2))
            name = fh.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<B", fh.read(1))
            dims = struct.unpack("<" + "I" * ndim, fh.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(fh.read(4 * n), "<f4").reshape(dims)
            out[name] = data
    return out


class TestWeightsBin:
    def test_roundtrip(self, tmp_path):
        params = M.init_params(0)
        path = str(tmp_path / "weights.bin")
        aot.write_weights(path, params)
        loaded = read_weights(path)
        # unsharded weights round-trip exactly
        for name in aot.weight_order():
            np.testing.assert_array_equal(
                loaded[name], np.asarray(params[name], np.float32))
        # shard slices present and consistent with shard_layer
        aw, mw = M.shard_layer(params, 0, 2, 1)
        np.testing.assert_array_equal(loaded["layers.0.wq.tp2.r1"], aw[1])
        np.testing.assert_array_equal(loaded["layers.0.w2.tp2.r1"], mw[2])

    def test_shard_columns_reassemble(self, tmp_path):
        params = M.init_params(0)
        path = str(tmp_path / "weights.bin")
        aot.write_weights(path, params)
        loaded = read_weights(path)
        for tp in (2, 4):
            cols = [loaded[f"layers.1.wq.tp{tp}.r{r}"] for r in range(tp)]
            np.testing.assert_array_equal(
                np.concatenate(cols, axis=1), loaded["layers.1.wq"])


class TestManifest:
    def test_artifact_defs_cover_grid(self):
        names = {n for n, _, _, _ in aot.artifact_defs()}
        for b in CFG.batch_buckets:
            assert f"embed_prefill_b{b}" in names
            assert f"full_decode_b{b}" in names
            for tp in CFG.tp_degrees:
                for role in ("attn", "mlp"):
                    for phase in ("prefill", "decode"):
                        assert f"{role}_{phase}_tp{tp}_b{b}" in names
        assert len(names) == len(list(aot.artifact_defs())), "duplicate names"

    def test_param_shapes_match_model(self):
        for name, _, params, _ in aot.artifact_defs():
            if name == "attn_prefill_tp2_b4":
                shapes = {n: s.shape for n, s in params}
                assert shapes["x"] == (4, CFG.prompt_len, CFG.hidden)
                assert shapes["wq"] == (CFG.hidden, CFG.hidden // 2)
                assert shapes["wo"] == (CFG.hidden // 2, CFG.hidden)
                return
        pytest.fail("artifact not found")

    def test_weight_order_matches_shapes(self):
        params = M.init_params(0)
        for name in aot.weight_order():
            assert tuple(aot.weight_shape(name)) == params[name].shape


class TestLowering:
    @pytest.mark.parametrize(
        "only", ["mlp_prefill_tp2_b1", "attn_decode_tp4_b1", "embed_decode_b1"])
    def test_lowering_produces_parseable_hlo(self, tmp_path, only):
        env = dict(os.environ)
        res = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
             "--only", only],
            capture_output=True, text=True, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )
        assert res.returncode == 0, res.stderr
        hlo = (tmp_path / f"{only}.hlo.txt").read_text()
        assert hlo.startswith("HloModule"), hlo[:80]
        assert "ENTRY" in hlo
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert only in manifest["artifacts"]
        assert manifest["model"]["hidden"] == CFG.hidden

    def test_numeric_equivalence_of_lowered_fn(self):
        """The jitted artifact function equals the eager stage function —
        guards against a lowering wrapper bug (argument misordering)."""
        params = M.init_params(0)
        for name, fn, pspecs, _ in aot.artifact_defs():
            if name != "attn_prefill_tp2_b1":
                continue
            key = jax.random.PRNGKey(9)
            x = jax.random.normal(key, (1, CFG.prompt_len, CFG.hidden))
            aw, _ = M.shard_layer(params, 2, 2, 1)
            got = jax.jit(fn)(x, *aw)
            want = M.attn_prefill_partial(x, *aw, tp=2)
            for g, w in zip(got, want):
                np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
            return
        pytest.fail("artifact not found")
