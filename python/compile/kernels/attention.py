"""Flash-attention-style Pallas kernels (Layer 1).

The paper builds its runtime on FlashAttention; the insight — never
materialize the ``S×S`` score matrix in slow memory — is re-expressed here
for the TPU model rather than ported CUDA-style:

* the grid tiles queries into blocks (``block_q``), one grid step per
  ``(batch·head, q-block)``;
* K/V are streamed block-by-block (``block_k``) from the stage input —
  on a real TPU the BlockSpecs below place each tile in VMEM and the two
  matmuls (``q·kᵀ``, ``p·v``) on the MXU;
* the online-softmax state (running max ``m``, normalizer ``l``, output
  accumulator) lives in registers/VMEM scratch across the K loop.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls, so the kernels lower to plain HLO through the Pallas
interpreter and are validated numerically against ``ref.py``.

VMEM budget per grid step (see DESIGN.md §7): ``(block_q + 2·block_k)·dh``
floats plus the ``block_q×block_k`` score tile — with the default 16/16
blocks and ``dh=32`` under 8 KiB, far below the ~16 MiB VMEM of a TPU
core, leaving room to raise blocks to MXU-optimal 128×128 on real
hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Finite stand-in for -inf: keeps exp/max NaN-free for fully-masked rows.
NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k,
                    s_k, causal):
    """One (batch·head, q-block) grid step of causal flash attention."""
    qb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # [BQ, dh]
    q_pos = qb * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kb = s_k // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale  # [BQ, BK]
        if causal:
            k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows → zeros, not NaN
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=16, block_k=16,
                    interpret=True):
    """Tiled online-softmax attention.

    Args:
        q, k, v: ``[B, nh, S, dh]`` (``S`` divisible by the block sizes).
        causal: lower-triangular masking (requires ``S_q == S_k``).

    Returns:
        ``[B, nh, S, dh]``, same dtype as ``q``.
    """
    b, nh, s_q, dh = q.shape
    s_k = k.shape[2]
    assert k.shape == (b, nh, s_k, dh) and v.shape == (b, nh, s_k, dh)
    assert s_q % block_q == 0, f"S_q={s_q} not divisible by block_q={block_q}"
    assert s_k % block_k == 0, f"S_k={s_k} not divisible by block_k={block_k}"
    if causal:
        assert s_q == s_k, "causal mask assumes aligned q/k positions"

    bh = b * nh
    qf = q.reshape(bh, s_q, dh)
    kf = k.reshape(bh, s_k, dh)
    vf = v.reshape(bh, s_k, dh)

    kernel = functools.partial(
        _prefill_kernel,
        scale=1.0 / (dh ** 0.5),
        block_q=block_q,
        block_k=block_k,
        s_k=s_k,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, s_q // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s_k, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s_k, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_q, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, nh, s_q, dh)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, scale, block_k,
                   s_max):
    """One (batch·head) grid step of single-token cache attention."""
    q = q_ref[0].astype(jnp.float32)  # [1, dh]
    length = len_ref[0]

    num_kb = s_max // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale  # [1, BK]
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((k_pos < length)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, length, *, block_k=16, interpret=True):
    """Decode-step attention against a partially-filled KV cache.

    Args:
        q: ``[B, nh, 1, dh]``.
        k_cache, v_cache: ``[B, nh, S_max, dh]``, valid up to ``length``.
        length: scalar int32 (traced OK) — number of valid positions.

    Returns:
        ``[B, nh, 1, dh]``.
    """
    b, nh, s_max, dh = k_cache.shape
    assert q.shape == (b, nh, 1, dh)
    assert s_max % block_k == 0

    bh = b * nh
    qf = q.reshape(bh, 1, dh)
    kf = k_cache.reshape(bh, s_max, dh)
    vf = v_cache.reshape(bh, s_max, dh)
    len_arr = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel,
        scale=1.0 / (dh ** 0.5),
        block_k=block_k,
        s_max=s_max,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, 1, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_max, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_max, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, dh), q.dtype),
        interpret=interpret,
    )(len_arr, qf, kf, vf)
    return out.reshape(b, nh, 1, dh)
