"""Pure-jnp oracles for the Pallas kernels.

These are the correctness contracts: every Pallas kernel in this package
must match its reference here to float32 tolerance across the shape/dtype
sweeps in ``python/tests/``.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """Naive softmax attention.

    Args:
        q, k, v: ``[B, nh, S, dh]``.
        causal: apply a lower-triangular mask.

    Returns:
        ``[B, nh, S, dh]`` attention output.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    if causal:
        s_q, s_k = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((s_q, s_k), dtype=bool), k=s_k - s_q)
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def decode_attention_ref(q, k_cache, v_cache, length):
    """Single-token attention against a (partially filled) KV cache.

    Args:
        q: ``[B, nh, 1, dh]`` query of the current token.
        k_cache, v_cache: ``[B, nh, S_max, dh]``; positions ``>= length``
            are garbage and must be masked out.
        length: scalar int — number of valid cache positions (the current
            token's K/V must already be written at ``length - 1``).

    Returns:
        ``[B, nh, 1, dh]``.
    """
    dh = q.shape[-1]
    s_max = k_cache.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    mask = (jnp.arange(s_max) < length)[None, None, None, :]
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v_cache)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """RMSNorm over the last axis."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * scale / jnp.sqrt(var + eps)
