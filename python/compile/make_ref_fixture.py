"""Emit the checked-in fixture for the Rust `ReferenceBackend` parity test.

Builds a tiny demo model (2 layers, H=16) with the same weight layout the
AOT step uses, runs prefill + greedy decode through the pure-jnp oracles
in ``kernels/ref.py`` (the numerics contract the Rust reference backend
mirrors), and writes:

* ``manifest.json`` / ``weights.bin`` — loadable by the Rust runtime
  layer exactly like a real artifacts directory (no ``.hlo.txt`` files:
  the reference backend executes stage names directly);
* ``golden.json`` — prompt tokens, post-prefill logits, and the greedy
  token sequence the Rust side must reproduce.

``--draft`` instead emits a **draft** companion model into
``<out-dir>/draft/`` for the speculative-decoding tests: a 1-layer
truncation of the target (layer 0 + embeddings + head, same vocabulary /
prompt length / context), plus a golden that pins the draft's own greedy
stream and — per (prompt, k) case — the exact propose/verify acceptance
pattern a `SpeculativeSession` over the two models must reproduce
(round count, proposed and accepted totals). The simulation here
teacher-forces the draft on the target's greedy stream, which is exactly
the state the Rust session maintains via rollback + commit, so the
patterns are bit-honest, not approximations.

Usage::

    python -m compile.make_ref_fixture --out-dir ../rust/tests/fixtures/ref_demo
    python -m compile.make_ref_fixture --out-dir ../rust/tests/fixtures/ref_demo --draft
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import aot
from . import model as M
from .kernels.ref import attention_ref, decode_attention_ref, rmsnorm_ref

CFG = M.DemoConfig(
    layers=2,
    hidden=16,
    heads=2,
    vocab=256,
    prompt_len=8,
    max_seq=16,
    tp_degrees=(1, 2),
    batch_buckets=(1, 2),
)

# The draft is a 1-layer truncation of the target: same vocabulary,
# prompt length and max_seq (hard requirements of SpeculativeSession),
# same width so it can reuse the target's layer-0 / embedding / head
# weights verbatim.
DRAFT_CFG = M.DemoConfig(
    layers=1,
    hidden=16,
    heads=2,
    vocab=256,
    prompt_len=8,
    max_seq=16,
    tp_degrees=(1,),
    batch_buckets=(1, 2),
)

PROMPT = "hexgen parity"
DECODE_STEPS = 6

# Prompts the speculative golden covers. The set is chosen so that the
# acceptance patterns across SPEC_KS empirically include full accepts
# (m == k_eff > 0), partial accepts (0 < m < k_eff) and zero accepts
# (m == 0 with k_eff > 0) — asserted below so a regenerated fixture
# cannot silently lose coverage of a rollback path.
SPEC_PROMPTS = (PROMPT, "the quick brown fox", "speculative decode")
SPEC_KS = (1, 2, 3)


def encode(text: str, prompt_len: int) -> list:
    """Mirror rust/src/runtime/tokenizer.rs: bytes, left-truncate, left-pad."""
    bs = list(text.encode("utf-8"))[-prompt_len:]
    return [0] * (prompt_len - len(bs)) + bs


def layer_forward_prefill(x, params, layer, cfg):
    """One layer, TP=1, built on the ref.py oracles (not the Pallas path)."""
    (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(params, layer, 1, 0, cfg)
    b, s, _ = x.shape
    nh, dh = cfg.heads, cfg.head_dim
    xn = rmsnorm_ref(x, ln1)
    q = (xn @ wq).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = (xn @ wk).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = (xn @ wv).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    attn = attention_ref(q, k, v, causal=True)
    partial = attn.transpose(0, 2, 1, 3).reshape(b, s, nh * dh) @ wo
    x = x + partial
    x = x + jax.nn.relu(rmsnorm_ref(x, ln2) @ w1) @ w2
    k_cache = jnp.zeros((b, nh, cfg.max_seq, dh), jnp.float32).at[:, :, :s].set(k)
    v_cache = jnp.zeros((b, nh, cfg.max_seq, dh), jnp.float32).at[:, :, :s].set(v)
    return x, k_cache, v_cache


def layer_forward_decode(x, params, layer, k_cache, v_cache, pos, cfg):
    (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(params, layer, 1, 0, cfg)
    b = x.shape[0]
    nh, dh = cfg.heads, cfg.head_dim
    xn = rmsnorm_ref(x, ln1)
    q = (xn @ wq).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    k_new = (xn @ wk).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    v_new = (xn @ wv).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    k_cache = k_cache.at[:, :, pos : pos + 1].set(k_new)
    v_cache = v_cache.at[:, :, pos : pos + 1].set(v_new)
    attn = decode_attention_ref(q, k_cache, v_cache, pos + 1)
    partial = attn.transpose(0, 2, 1, 3).reshape(b, 1, nh * dh) @ wo
    x = x + partial
    x = x + jax.nn.relu(rmsnorm_ref(x, ln2) @ w1) @ w2
    return x, k_cache, v_cache


def lm_head(x, params):
    return rmsnorm_ref(x[:, -1, :], params["final_ln"]) @ params["lm_head"]


def margin_of(logits):
    srt = np.sort(logits)
    return float(srt[-1] - srt[-2])


class Decoder:
    """Prefill-then-feed incremental decode state for one prompt.

    ``feed`` consumes one generated token (the j-th fed token lands at
    KV position ``prompt_len + j``, mirroring the Rust decode loop) and
    returns the next-token logits as float64.
    """

    def __init__(self, params, cfg, prompt_tokens):
        self.params, self.cfg = params, cfg
        x = M.embed(jnp.asarray([prompt_tokens], jnp.int32), params["embed"])
        self.caches = []
        for i in range(cfg.layers):
            x, kc, vc = layer_forward_prefill(x, params, i, cfg)
            self.caches.append((kc, vc))
        self.prefill_logits = np.asarray(lm_head(x, params)[0], np.float64)
        self.consumed = 0

    def feed(self, tok):
        pos = self.cfg.prompt_len + self.consumed
        x = M.embed(jnp.asarray([[tok]], jnp.int32), self.params["embed"])
        for i in range(self.cfg.layers):
            kc, vc = self.caches[i]
            x, kc, vc = layer_forward_decode(x, self.params, i, kc, vc, pos, self.cfg)
            self.caches[i] = (kc, vc)
        self.consumed += 1
        return np.asarray(lm_head(x, self.params)[0], np.float64)


def greedy_decode(params, cfg, prompt_tokens, steps):
    """Prefill + `steps` greedy tokens; returns (tokens, margins, prefill_logits)."""
    d = Decoder(params, cfg, prompt_tokens)
    logits = d.prefill_logits
    out = [int(np.argmax(logits))]
    margins = [margin_of(logits)]
    for _ in range(1, steps):
        logits = d.feed(out[-1])
        out.append(int(np.argmax(logits)))
        margins.append(margin_of(logits))
    return out, margins, d.prefill_logits


def draft_propose(params, cfg, prompt_tokens, committed, k):
    """Draft proposals for one speculative round, teacher-forced.

    ``committed`` is the emitted (target) stream so far; the draft has
    consumed everything but the last token, which is its pending input —
    exactly the state SpeculativeSession maintains through rollback and
    commit. Returns (proposals, argmax margins).
    """
    d = Decoder(params, cfg, prompt_tokens)
    for t in committed[:-1]:
        d.feed(t)
    props, margins = [], []
    cur = committed[-1]
    for _ in range(k):
        logits = d.feed(cur)
        cur = int(np.argmax(logits))
        props.append(cur)
        margins.append(margin_of(logits))
    return props, margins


def simulate_spec(dparams, dcfg, prompt_tokens, target_tokens, k, max_new):
    """Replay the spec_round protocol against a known target stream.

    Greedy verification means every committed token equals the target's
    own greedy token, so the target side needs no re-execution: round
    boundaries and acceptance counts depend only on where the draft's
    proposals diverge from ``target_tokens``. Returns (rounds, margins)
    with one ``{"k_eff", "m"}`` entry per round.
    """
    g, rounds, margins = 1, [], []
    while g < max_new:
        k_eff = min(k, max_new - g - 1)
        if k_eff > 0:
            props, ms = draft_propose(dparams, dcfg, prompt_tokens, target_tokens[:g], k_eff)
            margins += ms
        else:
            props = []
        m = 0
        while m < k_eff and props[m] == target_tokens[g + m]:
            m += 1
        rounds.append({"k_eff": k_eff, "m": m})
        g += m + 1
    return rounds, margins


def write_model(out_dir, name, params, cfg, seed):
    """weights.bin + manifest.json, exactly like a real artifacts dir."""
    aot.write_weights(os.path.join(out_dir, "weights.bin"), params, cfg)
    manifest = {
        "model": {
            "name": name,
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "vocab": cfg.vocab,
            "prompt_len": cfg.prompt_len,
            "max_seq": cfg.max_seq,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
        },
        "tp_degrees": list(cfg.tp_degrees),
        "batch_buckets": list(cfg.batch_buckets),
        "weight_order": aot.weight_order(cfg),
        "seed": seed,
        "artifacts": {
            aname: {
                "file": f"{aname}.hlo.txt",
                "params": [aot.shape_entry(n, s) for n, s in params_spec],
                "outputs": outputs,
            }
            for aname, _, params_spec, outputs in aot.artifact_defs(cfg)
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)


def draft_params_from(params):
    """Truncate the target to one layer: layer 0 + embeddings + head."""
    keep = ("embed", "final_ln", "lm_head")
    return {
        k: v
        for k, v in params.items()
        if k in keep or k.startswith("layers.0.")
    }


def emit_target(out_dir, params, seed):
    tokens = encode(PROMPT, CFG.prompt_len)
    out_tokens, margins, prefill_logits = greedy_decode(params, CFG, tokens, DECODE_STEPS)

    # Greedy decisions must be robust to f32 reimplementation noise.
    assert min(margins) > 1e-3, f"argmax margin too small: {margins}"

    write_model(out_dir, "ref-demo-2l-16h", params, CFG, seed)
    golden = {
        "prompt": PROMPT,
        "prompt_tokens": tokens,
        "prefill_logits": [float(v) for v in prefill_logits],
        "greedy_tokens": out_tokens,
        "argmax_margins": margins,
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as fh:
        json.dump(golden, fh, indent=1)
    print(f"wrote fixture to {out_dir}")
    print(f"prompt tokens : {tokens}")
    print(f"greedy tokens : {out_tokens}")
    print(f"min margin    : {min(margins):.4f}")


def emit_draft(out_dir, params, seed):
    dparams = draft_params_from(params)
    all_margins = []

    # The draft's own greedy stream over the canonical prompt — pins the
    # draft model solo against the Rust reference backend.
    tokens = encode(PROMPT, DRAFT_CFG.prompt_len)
    dtokens, dmargins, dprefill = greedy_decode(dparams, DRAFT_CFG, tokens, DECODE_STEPS)
    all_margins += dmargins

    # Per (prompt, k): the target stream and the acceptance pattern a
    # SpeculativeSession must reproduce round for round.
    cases = []
    for prompt in SPEC_PROMPTS:
        ptoks = encode(prompt, CFG.prompt_len)
        ttokens, tmargins, _ = greedy_decode(params, CFG, ptoks, DECODE_STEPS)
        all_margins += tmargins
        for k in SPEC_KS:
            rounds, smargins = simulate_spec(
                dparams, DRAFT_CFG, ptoks, ttokens, k, DECODE_STEPS
            )
            all_margins += smargins
            cases.append(
                {
                    "prompt": prompt,
                    "k": k,
                    "max_new": DECODE_STEPS,
                    "target_tokens": ttokens,
                    "rounds": rounds,
                    "rounds_total": len(rounds),
                    "proposed": sum(r["k_eff"] for r in rounds),
                    "accepted": sum(r["m"] for r in rounds),
                }
            )

    # Every greedy decision the Rust tests replay must be f32-robust.
    assert min(all_margins) > 1e-3, f"argmax margin too small: {min(all_margins)}"

    # The golden must cover every acceptance shape or the rollback paths
    # go untested: full accepts, partial accepts, zero accepts.
    shapes = [(r["k_eff"], r["m"]) for c in cases for r in c["rounds"]]
    has_full = any(k > 0 and m == k for k, m in shapes)
    has_partial = any(0 < m < k for k, m in shapes)
    has_zero = any(k > 0 and m == 0 for k, m in shapes)
    assert has_full and has_partial and has_zero, (
        f"acceptance coverage incomplete (full={has_full}, partial={has_partial}, "
        f"zero={has_zero}); adjust SPEC_PROMPTS: {shapes}"
    )

    write_model(out_dir, "ref-demo-draft-1l-16h", dparams, DRAFT_CFG, seed)
    golden = {
        "prompt": PROMPT,
        "prompt_tokens": tokens,
        "prefill_logits": [float(v) for v in dprefill],
        "greedy_tokens": dtokens,
        "argmax_margins": dmargins,
        "spec_cases": cases,
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as fh:
        json.dump(golden, fh, indent=1)
    print(f"wrote draft fixture to {out_dir}")
    print(f"draft greedy tokens : {dtokens}")
    for c in cases:
        pat = " ".join(f"{r['m']}/{r['k_eff']}" for r in c["rounds"])
        print(f"  k={c['k']} {c['prompt']!r:>24}: rounds [{pat}]")
    print(f"min margin          : {min(all_margins):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../rust/tests/fixtures/ref_demo")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--draft",
        action="store_true",
        help="emit the speculative-decoding draft fixture into <out-dir>/draft/",
    )
    args = ap.parse_args()

    params = M.init_params(args.seed, CFG)
    if args.draft:
        out_dir = os.path.join(args.out_dir, "draft")
        os.makedirs(out_dir, exist_ok=True)
        emit_draft(out_dir, params, args.seed)
    else:
        os.makedirs(args.out_dir, exist_ok=True)
        emit_target(args.out_dir, params, args.seed)


if __name__ == "__main__":
    main()
