"""Emit the checked-in fixture for the Rust `ReferenceBackend` parity test.

Builds a tiny demo model (2 layers, H=16) with the same weight layout the
AOT step uses, runs prefill + greedy decode through the pure-jnp oracles
in ``kernels/ref.py`` (the numerics contract the Rust reference backend
mirrors), and writes:

* ``manifest.json`` / ``weights.bin`` — loadable by the Rust runtime
  layer exactly like a real artifacts directory (no ``.hlo.txt`` files:
  the reference backend executes stage names directly);
* ``golden.json`` — prompt tokens, post-prefill logits, and the greedy
  token sequence the Rust side must reproduce.

Usage: ``python -m compile.make_ref_fixture --out-dir ../rust/tests/fixtures/ref_demo``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import aot
from . import model as M
from .kernels.ref import attention_ref, decode_attention_ref, rmsnorm_ref

CFG = M.DemoConfig(
    layers=2,
    hidden=16,
    heads=2,
    vocab=256,
    prompt_len=8,
    max_seq=16,
    tp_degrees=(1, 2),
    batch_buckets=(1, 2),
)

PROMPT = "hexgen parity"
DECODE_STEPS = 6


def encode(text: str, prompt_len: int) -> list:
    """Mirror rust/src/runtime/tokenizer.rs: bytes, left-truncate, left-pad."""
    bs = list(text.encode("utf-8"))[-prompt_len:]
    return [0] * (prompt_len - len(bs)) + bs


def layer_forward_prefill(x, params, layer, cfg):
    """One layer, TP=1, built on the ref.py oracles (not the Pallas path)."""
    (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(params, layer, 1, 0, cfg)
    b, s, _ = x.shape
    nh, dh = cfg.heads, cfg.head_dim
    xn = rmsnorm_ref(x, ln1)
    q = (xn @ wq).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = (xn @ wk).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = (xn @ wv).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    attn = attention_ref(q, k, v, causal=True)
    partial = attn.transpose(0, 2, 1, 3).reshape(b, s, nh * dh) @ wo
    x = x + partial
    x = x + jax.nn.relu(rmsnorm_ref(x, ln2) @ w1) @ w2
    k_cache = jnp.zeros((b, nh, cfg.max_seq, dh), jnp.float32).at[:, :, :s].set(k)
    v_cache = jnp.zeros((b, nh, cfg.max_seq, dh), jnp.float32).at[:, :, :s].set(v)
    return x, k_cache, v_cache


def layer_forward_decode(x, params, layer, k_cache, v_cache, pos, cfg):
    (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(params, layer, 1, 0, cfg)
    b = x.shape[0]
    nh, dh = cfg.heads, cfg.head_dim
    xn = rmsnorm_ref(x, ln1)
    q = (xn @ wq).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    k_new = (xn @ wk).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    v_new = (xn @ wv).reshape(b, 1, nh, dh).transpose(0, 2, 1, 3)
    k_cache = k_cache.at[:, :, pos : pos + 1].set(k_new)
    v_cache = v_cache.at[:, :, pos : pos + 1].set(v_new)
    attn = decode_attention_ref(q, k_cache, v_cache, pos + 1)
    partial = attn.transpose(0, 2, 1, 3).reshape(b, 1, nh * dh) @ wo
    x = x + partial
    x = x + jax.nn.relu(rmsnorm_ref(x, ln2) @ w1) @ w2
    return x, k_cache, v_cache


def lm_head(x, params):
    return rmsnorm_ref(x[:, -1, :], params["final_ln"]) @ params["lm_head"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../rust/tests/fixtures/ref_demo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = CFG
    params = M.init_params(args.seed, cfg)

    tokens = encode(PROMPT, cfg.prompt_len)
    x = M.embed(jnp.asarray([tokens], jnp.int32), params["embed"])
    caches = []
    for i in range(cfg.layers):
        x, kc, vc = layer_forward_prefill(x, params, i, cfg)
        caches.append((kc, vc))
    logits = lm_head(x, params)
    prefill_logits = np.asarray(logits[0], np.float64)

    out_tokens = [int(np.argmax(prefill_logits))]
    margins = [float(np.sort(prefill_logits)[-1] - np.sort(prefill_logits)[-2])]
    for step in range(1, DECODE_STEPS):
        pos = cfg.prompt_len + step - 1
        x = M.embed(jnp.asarray([[out_tokens[-1]]], jnp.int32), params["embed"])
        for i in range(cfg.layers):
            kc, vc = caches[i]
            x, kc, vc = layer_forward_decode(x, params, i, kc, vc, pos, cfg)
            caches[i] = (kc, vc)
        step_logits = np.asarray(lm_head(x, params)[0], np.float64)
        out_tokens.append(int(np.argmax(step_logits)))
        srt = np.sort(step_logits)
        margins.append(float(srt[-1] - srt[-2]))

    # Greedy decisions must be robust to f32 reimplementation noise.
    assert min(margins) > 1e-3, f"argmax margin too small: {margins}"

    aot.write_weights(os.path.join(args.out_dir, "weights.bin"), params, cfg)
    manifest = {
        "model": {
            "name": "ref-demo-2l-16h",
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "vocab": cfg.vocab,
            "prompt_len": cfg.prompt_len,
            "max_seq": cfg.max_seq,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
        },
        "tp_degrees": list(cfg.tp_degrees),
        "batch_buckets": list(cfg.batch_buckets),
        "weight_order": aot.weight_order(cfg),
        "seed": args.seed,
        "artifacts": {
            name: {
                "file": f"{name}.hlo.txt",
                "params": [aot.shape_entry(n, s) for n, s in params_spec],
                "outputs": outputs,
            }
            for name, _, params_spec, outputs in aot.artifact_defs(cfg)
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)

    golden = {
        "prompt": PROMPT,
        "prompt_tokens": tokens,
        "prefill_logits": [float(v) for v in prefill_logits],
        "greedy_tokens": out_tokens,
        "argmax_margins": margins,
    }
    with open(os.path.join(args.out_dir, "golden.json"), "w") as fh:
        json.dump(golden, fh, indent=1)
    print(f"wrote fixture to {args.out_dir}")
    print(f"prompt tokens : {tokens}")
    print(f"greedy tokens : {out_tokens}")
    print(f"min margin    : {min(margins):.4f}")


if __name__ == "__main__":
    main()
