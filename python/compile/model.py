"""Layer 2: the demo transformer as TP-shardable stage functions.

The model follows the paper's §2 formulation (MHA + ReLU MLP with 4H
inner width, pre-norm residuals) at a small scale the CPU PJRT client can
serve. Every function here is *stage-granular* so the Rust coordinator
can compose arbitrary asymmetric TP×PP plans:

* TP sharding is Megatron-style: ``wq/wk/wv`` column-sharded by head
  groups, ``wo`` row-sharded; ``w1`` column-, ``w2`` row-sharded. Each
  shard computes a **partial** projection output (no residual); the Rust
  side all-reduces partials and adds the residual — two all-reduces per
  layer, exactly the communication the paper's Eq. 5 models.
* RMSNorm is computed redundantly per shard (input is replicated).
* KV caches are per-shard (head-group slice) and owned by Rust between
  steps; decode functions return functionally-updated caches.

These functions must match ``DemoConfig`` ↔ ``ModelSpec::demo()`` on the
Rust side.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention, flash_decode
from .kernels.ref import rmsnorm_ref


@dataclass(frozen=True)
class DemoConfig:
    """Architecture of the served demo model (mirror of ModelSpec::demo)."""

    layers: int = 6
    hidden: int = 128
    heads: int = 4
    vocab: int = 256
    # Serving shape contract (fixed, padded):
    prompt_len: int = 32
    max_seq: int = 64  # prompt + up to 32 generated tokens
    # TP degrees artifacts are emitted for:
    tp_degrees: tuple = (1, 2, 4)
    # Batch-size buckets artifacts are emitted for:
    batch_buckets: tuple = (1, 4)

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def ffn(self) -> int:
        return 4 * self.hidden


CFG = DemoConfig()


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(seed: int, cfg: DemoConfig = CFG) -> dict:
    """Seeded dense weights, scaled for stable activations at init."""
    key = jax.random.PRNGKey(seed)
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    p = {}

    def normal(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32) * scale)

    keys = iter(jax.random.split(key, 4 + cfg.layers * 6))
    p["embed"] = normal(next(keys), (v, h), 0.02)
    for i in range(cfg.layers):
        p[f"layers.{i}.ln1"] = jnp.ones((h,), jnp.float32)
        p[f"layers.{i}.wq"] = normal(next(keys), (h, h), h ** -0.5)
        p[f"layers.{i}.wk"] = normal(next(keys), (h, h), h ** -0.5)
        p[f"layers.{i}.wv"] = normal(next(keys), (h, h), h ** -0.5)
        p[f"layers.{i}.wo"] = normal(next(keys), (h, h), h ** -0.5)
        p[f"layers.{i}.ln2"] = jnp.ones((h,), jnp.float32)
        p[f"layers.{i}.w1"] = normal(next(keys), (h, f), h ** -0.5)
        p[f"layers.{i}.w2"] = normal(next(keys), (f, h), f ** -0.5)
    p["final_ln"] = jnp.ones((h,), jnp.float32)
    p["lm_head"] = normal(next(keys), (h, v), h ** -0.5)
    return p


def shard_layer(p: dict, layer: int, tp: int, rank: int, cfg: DemoConfig = CFG):
    """Megatron slices of one layer's weights for shard ``rank`` of ``tp``.

    Returns ``(attn_weights, mlp_weights)`` tuples as consumed by
    :func:`attn_prefill_partial` / :func:`mlp_partial`.
    """
    assert cfg.heads % tp == 0, "tp must divide heads"
    h = cfg.hidden
    hs = h // tp  # sharded projection width (head-group columns)
    fs = cfg.ffn // tp
    pre = f"layers.{layer}."
    sl = slice(rank * hs, (rank + 1) * hs)
    fsl = slice(rank * fs, (rank + 1) * fs)
    attn = (
        p[pre + "ln1"],
        p[pre + "wq"][:, sl],
        p[pre + "wk"][:, sl],
        p[pre + "wv"][:, sl],
        p[pre + "wo"][sl, :],
    )
    mlp = (p[pre + "ln2"], p[pre + "w1"][:, fsl], p[pre + "w2"][fsl, :])
    return attn, mlp


# --------------------------------------------------------------------------
# Stage functions (AOT-lowered individually; weights are runtime params)
# --------------------------------------------------------------------------

def embed(tokens, emb):
    """Token embedding lookup. tokens ``[B, S]`` int32 → ``[B, S, H]``."""
    return jnp.take(emb, tokens, axis=0)


def _split_heads(x, nh_shard, dh):
    """[B, S, hs] → [B, nh_shard, S, dh]."""
    b, s, _ = x.shape
    return x.reshape(b, s, nh_shard, dh).transpose(0, 2, 1, 3)


def _merge_heads(x):
    """[B, nh_shard, S, dh] → [B, S, hs]."""
    b, nh, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, nh * dh)


def attn_prefill_partial(x, ln, wq, wk, wv, wo, *, cfg: DemoConfig = CFG,
                         tp: int = 1, interpret: bool = True):
    """Prefill attention, one TP shard.

    Args:
        x: ``[B, S, H]`` replicated stage input.
        ln: ``[H]`` RMSNorm scale; ``wq/wk/wv``: ``[H, H/tp]``;
        ``wo``: ``[H/tp, H]``.

    Returns:
        ``(partial_out [B,S,H], k_cache [B,nh/tp,S_max,dh],
        v_cache [B,nh/tp,S_max,dh])`` — caches zero-padded to ``max_seq``,
        filled in ``[0, S)``.
    """
    b, s, h = x.shape
    nh_shard = (cfg.heads // tp)
    dh = cfg.head_dim
    xn = rmsnorm_ref(x, ln)
    q = _split_heads(xn @ wq, nh_shard, dh)
    k = _split_heads(xn @ wk, nh_shard, dh)
    v = _split_heads(xn @ wv, nh_shard, dh)
    attn = flash_attention(q, k, v, causal=True, interpret=interpret)
    partial = _merge_heads(attn) @ wo  # [B, S, H] partial sum
    k_cache = jnp.zeros((b, nh_shard, cfg.max_seq, dh), x.dtype)
    v_cache = jnp.zeros((b, nh_shard, cfg.max_seq, dh), x.dtype)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, 0, 0))
    return partial, k_cache, v_cache


def attn_decode_partial(x, k_cache, v_cache, pos, ln, wq, wk, wv, wo, *,
                        cfg: DemoConfig = CFG, tp: int = 1,
                        interpret: bool = True):
    """Decode-step attention, one TP shard.

    Args:
        x: ``[B, 1, H]`` current-token hidden state.
        k_cache/v_cache: ``[B, nh/tp, S_max, dh]`` shard caches.
        pos: scalar int32 — write position of the current token
            (= number of tokens already cached).

    Returns:
        ``(partial_out [B,1,H], k_cache', v_cache')``.
    """
    nh_shard = cfg.heads // tp
    dh = cfg.head_dim
    xn = rmsnorm_ref(x, ln)
    q = _split_heads(xn @ wq, nh_shard, dh)      # [B, nh, 1, dh]
    k_new = _split_heads(xn @ wk, nh_shard, dh)
    v_new = _split_heads(xn @ wv, nh_shard, dh)
    pos = jnp.asarray(pos, jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, 0, pos, 0))
    attn = flash_decode(q, k_cache, v_cache, pos + 1, interpret=interpret)
    partial = _merge_heads(attn) @ wo
    return partial, k_cache, v_cache


def mlp_partial(x, ln, w1, w2):
    """ReLU MLP (paper §2), one TP shard: partial of the down-projection."""
    xn = rmsnorm_ref(x, ln)
    return jax.nn.relu(xn @ w1) @ w2


def lm_head_last(x, ln, w):
    """Logits of the last position: ``[B, S, H] → [B, V]``."""
    xl = rmsnorm_ref(x[:, -1, :], ln)
    return xl @ w


# --------------------------------------------------------------------------
# Full-model reference (tests + fused single-executable artifacts)
# --------------------------------------------------------------------------

def forward_prefill_full(tokens, params, *, cfg: DemoConfig = CFG,
                         interpret: bool = True):
    """Whole-model prefill, TP=1 (the composition oracle).

    Returns ``(logits [B,V], k_caches, v_caches)`` with per-layer caches
    stacked on axis 0: ``[L, B, nh, S_max, dh]``.
    """
    x = embed(tokens, params["embed"])
    kcs, vcs = [], []
    for i in range(cfg.layers):
        (ln1, wq, wk, wv, wo), (ln2, w1, w2) = shard_layer(params, i, 1, 0, cfg)
        part, kc, vc = attn_prefill_partial(
            x, ln1, wq, wk, wv, wo, cfg=cfg, tp=1, interpret=interpret)
        x = x + part
        x = x + mlp_partial(x, ln2, w1, w2)
        kcs.append(kc)
        vcs.append(vc)
    logits = lm_head_last(x, params["final_ln"], params["lm_head"])
    return logits, jnp.stack(kcs), jnp.stack(vcs)


def forward_decode_full(token, k_caches, v_caches, pos, params, *,
                        cfg: DemoConfig = CFG, interpret: bool = True):
    """Whole-model decode step, TP=1.

    Args:
        token: ``[B, 1]`` int32; ``k_caches/v_caches``:
        ``[L, B, nh, S_max, dh]``; pos: scalar int32.

    Returns ``(logits [B,V], k_caches', v_caches')``.
    """
    x = embed(token, params["embed"])
    new_k, new_v = [], []
    for i in range(cfg.layers):
        (ln1, wq, wk, wv, wo), (ln2, w1, w2) = shard_layer(params, i, 1, 0, cfg)
        part, kc, vc = attn_decode_partial(
            x, k_caches[i], v_caches[i], pos, ln1, wq, wk, wv, wo,
            cfg=cfg, tp=1, interpret=interpret)
        x = x + part
        x = x + mlp_partial(x, ln2, w1, w2)
        new_k.append(kc)
        new_v.append(vc)
    logits = lm_head_last(x, params["final_ln"], params["lm_head"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)
