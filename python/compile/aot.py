"""AOT lowering: JAX stage functions → HLO text + weights + manifest.

This is the only place Python touches the model. It runs once
(``make artifacts``) and emits, under ``artifacts/``:

* ``<name>.hlo.txt`` — one HLO-text module per (role × phase × TP degree ×
  batch bucket) stage variant, plus fused whole-model variants. HLO
  **text** is the interchange format: the ``xla`` crate's xla_extension
  0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit instruction ids), but
  its text parser reassigns ids cleanly (see /opt/xla-example/README.md).
* ``weights.bin`` — seeded model weights plus every TP shard slice, in a
  simple named-tensor format (parsed by ``rust/src/runtime/weights.rs``).
* ``manifest.json`` — shapes and parameter order of every artifact.

Weights are *runtime parameters* of the HLO modules (not baked
constants), so each shape-class compiles once and all layers/shards reuse
the executable.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .model import CFG


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_entry(name, s):
    return {
        "name": name,
        "shape": list(s.shape),
        "dtype": str(s.dtype),
    }


# --------------------------------------------------------------------------
# Artifact definitions
# --------------------------------------------------------------------------

def artifact_defs(cfg=CFG):
    """Yield (name, fn, [(param_name, ShapeDtypeStruct)], [output names])."""
    h, v, f = cfg.hidden, cfg.vocab, cfg.ffn
    s_in, s_max, dh = cfg.prompt_len, cfg.max_seq, cfg.head_dim
    i32 = jnp.int32

    for b in cfg.batch_buckets:
        yield (
            f"embed_prefill_b{b}",
            M.embed,
            [("tokens", spec((b, s_in), i32)), ("embed", spec((v, h)))],
            ["x"],
        )
        yield (
            f"embed_decode_b{b}",
            M.embed,
            [("tokens", spec((b, 1), i32)), ("embed", spec((v, h)))],
            ["x"],
        )
        yield (
            f"lm_head_prefill_b{b}",
            M.lm_head_last,
            [("x", spec((b, s_in, h))), ("final_ln", spec((h,))),
             ("lm_head", spec((h, v)))],
            ["logits"],
        )
        yield (
            f"lm_head_decode_b{b}",
            M.lm_head_last,
            [("x", spec((b, 1, h))), ("final_ln", spec((h,))),
             ("lm_head", spec((h, v)))],
            ["logits"],
        )
        for tp in cfg.tp_degrees:
            hs, fs, nhs = h // tp, f // tp, cfg.heads // tp

            def attn_pre(x, ln, wq, wk, wv, wo, _tp=tp):
                return M.attn_prefill_partial(
                    x, ln, wq, wk, wv, wo, cfg=cfg, tp=_tp)

            yield (
                f"attn_prefill_tp{tp}_b{b}",
                attn_pre,
                [("x", spec((b, s_in, h))), ("ln1", spec((h,))),
                 ("wq", spec((h, hs))), ("wk", spec((h, hs))),
                 ("wv", spec((h, hs))), ("wo", spec((hs, h)))],
                ["partial", "k_cache", "v_cache"],
            )

            def attn_dec(x, kc, vc, pos, ln, wq, wk, wv, wo, _tp=tp):
                return M.attn_decode_partial(
                    x, kc, vc, pos, ln, wq, wk, wv, wo, cfg=cfg, tp=_tp)

            yield (
                f"attn_decode_tp{tp}_b{b}",
                attn_dec,
                [("x", spec((b, 1, h))),
                 ("k_cache", spec((b, nhs, s_max, dh))),
                 ("v_cache", spec((b, nhs, s_max, dh))),
                 ("pos", spec((), i32)),
                 ("ln1", spec((h,))), ("wq", spec((h, hs))),
                 ("wk", spec((h, hs))), ("wv", spec((h, hs))),
                 ("wo", spec((hs, h)))],
                ["partial", "k_cache", "v_cache"],
            )
            yield (
                f"mlp_prefill_tp{tp}_b{b}",
                M.mlp_partial,
                [("x", spec((b, s_in, h))), ("ln2", spec((h,))),
                 ("w1", spec((h, fs))), ("w2", spec((fs, h)))],
                ["partial"],
            )
            yield (
                f"mlp_decode_tp{tp}_b{b}",
                M.mlp_partial,
                [("x", spec((b, 1, h))), ("ln2", spec((h,))),
                 ("w1", spec((h, fs))), ("w2", spec((fs, h)))],
                ["partial"],
            )

        # Fused whole-model (TP=1) variants: the quickstart path and the
        # composition oracle for integration tests.
        wnames = weight_order(cfg)
        wspecs = [(n, spec(weight_shape(n, cfg))) for n in wnames]

        def full_pre(tokens, *ws):
            params = dict(zip(wnames, ws))
            return M.forward_prefill_full(tokens, params, cfg=cfg)

        yield (
            f"full_prefill_b{b}",
            full_pre,
            [("tokens", spec((b, s_in), i32))] + wspecs,
            ["logits", "k_caches", "v_caches"],
        )

        def full_dec(token, kc, vc, pos, *ws):
            params = dict(zip(wnames, ws))
            return M.forward_decode_full(token, kc, vc, pos, params, cfg=cfg)

        yield (
            f"full_decode_b{b}",
            full_dec,
            [("token", spec((b, 1), i32)),
             ("k_caches", spec((cfg.layers, b, cfg.heads, s_max, dh))),
             ("v_caches", spec((cfg.layers, b, cfg.heads, s_max, dh))),
             ("pos", spec((), i32))] + wspecs,
            ["logits", "k_caches", "v_caches"],
        )


def weight_order(cfg=CFG):
    """Canonical unsharded weight name order (manifest + weights.bin)."""
    names = ["embed"]
    for i in range(cfg.layers):
        names += [f"layers.{i}.{w}"
                  for w in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")]
    names += ["final_ln", "lm_head"]
    return names


def weight_shape(name, cfg=CFG):
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    if name == "embed":
        return (v, h)
    if name == "final_ln":
        return (h,)
    if name == "lm_head":
        return (h, v)
    leaf = name.split(".")[-1]
    return {
        "ln1": (h,), "ln2": (h,),
        "wq": (h, h), "wk": (h, h), "wv": (h, h), "wo": (h, h),
        "w1": (h, f), "w2": (f, h),
    }[leaf]


# --------------------------------------------------------------------------
# weights.bin
# --------------------------------------------------------------------------

MAGIC = b"HXGW"
VERSION = 1


def write_weights(path: str, params: dict, cfg=CFG):
    """Serialize unsharded weights + all TP shard slices.

    Format (little endian): magic ``HXGW``, u32 version, u32 count, then
    per tensor: u16 name_len, name utf-8, u8 ndim, u32 dims…, f32 data.
    """
    tensors = {}
    for name in weight_order(cfg):
        tensors[name] = np.asarray(params[name], np.float32)
    for tp in cfg.tp_degrees:
        if tp == 1:
            continue
        for i in range(cfg.layers):
            for r in range(tp):
                (ln1, wq, wk, wv, wo), (ln2, w1, w2) = M.shard_layer(
                    params, i, tp, r, cfg)
                base = f"layers.{i}"
                tensors[f"{base}.wq.tp{tp}.r{r}"] = np.asarray(wq, np.float32)
                tensors[f"{base}.wk.tp{tp}.r{r}"] = np.asarray(wk, np.float32)
                tensors[f"{base}.wv.tp{tp}.r{r}"] = np.asarray(wv, np.float32)
                tensors[f"{base}.wo.tp{tp}.r{r}"] = np.asarray(wo, np.float32)
                tensors[f"{base}.w1.tp{tp}.r{r}"] = np.asarray(w1, np.float32)
                tensors[f"{base}.w2.tp{tp}.r{r}"] = np.asarray(w2, np.float32)

    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            nb = name.encode("utf-8")
            fh.write(struct.pack("<H", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                fh.write(struct.pack("<I", d))
            fh.write(arr.astype("<f4").tobytes())


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = CFG
    manifest = {
        "model": {
            "name": "demo-6l-128h",
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "vocab": cfg.vocab,
            "prompt_len": cfg.prompt_len,
            "max_seq": cfg.max_seq,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
        },
        "tp_degrees": list(cfg.tp_degrees),
        "batch_buckets": list(cfg.batch_buckets),
        "weight_order": weight_order(cfg),
        "seed": args.seed,
        "artifacts": {},
    }

    for name, fn, params, outputs in artifact_defs(cfg):
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "params": [shape_entry(n, s) for n, s in params],
            "outputs": outputs,
        }
        if args.only and args.only not in name:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        specs = [s for _, s in params]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  lowered {name}: {len(text)} chars")

    weights = M.init_params(args.seed, cfg)
    write_weights(os.path.join(args.out_dir, "weights.bin"), weights, cfg)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifact defs, weights.bin, "
          f"manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
